"""Training step factory: grad accumulation, remat, AdamW, grad compression.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function suitable for jit with in/out shardings:

  * microbatching: the global batch is split into ``microbatches`` slices
    scanned sequentially with f32 gradient accumulation -- the standard
    memory lever for big models (activation footprint / microbatch);
  * remat: 'none' | 'full' | 'dots' activation checkpointing over the
    layer scan;
  * grad_sync: 'auto' leaves the gradient reduction to GSPMD (it fuses
    the reduce into the backward); 'compressed' runs the explicit int8
    ring all-reduce with error feedback over the dp axes (see
    optim/compression.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.transformer import init_params, loss_fn
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "full"
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    grad_sync: str = "auto"          # auto | compressed
    dp_axes: Tuple[str, ...] = ("data",)
    # gradient-accumulation dtype: f32 default; bf16 halves the sharded
    # accumulator for capacity-constrained giants (deepseek-v3 on 256
    # chips) at ~3 bits of accumulation precision over 16 microbatches.
    grad_acc_dtype: str = "float32"


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(tcfg.opt, params)}


def train_state_shape(cfg: ModelConfig, tcfg: TrainConfig):
    """Abstract train state via eval_shape (no allocation; dry-run path)."""
    return jax.eval_shape(
        lambda k: init_train_state(cfg, tcfg, k), jax.random.PRNGKey(0)
    )


def _microbatch(batch: Dict[str, jnp.ndarray], n: int):
    """[GB, ...] -> [n, GB/n, ...] for scanning."""
    def split(x):
        gb = x.shape[0]
        assert gb % n == 0, f"global batch {gb} % microbatches {n} != 0"
        return x.reshape((n, gb // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    def train_step(state, batch):
        params = state["params"]

        def loss_for(p, mb):
            loss, metrics = loss_fn(p, cfg, mb, remat=tcfg.remat)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_for, has_aux=True)

        if tcfg.microbatches > 1:
            mbs = _microbatch(batch, tcfg.microbatches)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g
                )
                return (g_acc, l_acc + loss), metrics

            acc_dt = (
                jnp.bfloat16 if tcfg.grad_acc_dtype == "bfloat16" else jnp.float32
            )
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (g_sum, loss_sum), metrics = jax.lax.scan(
                acc_step, (g0, jnp.float32(0)), mbs
            )
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, g_sum)
            loss = loss_sum / tcfg.microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        new_params, new_opt, opt_metrics = apply_updates(
            tcfg.opt, params, grads, state["opt"]
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, remat="none")
        return loss

    return eval_step
