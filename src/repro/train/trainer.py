"""Training step factory: grad accumulation, remat, AdamW, grad compression.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function suitable for jit with in/out shardings:

  * microbatching: the global batch is split into ``microbatches`` slices
    scanned sequentially with f32 gradient accumulation -- the standard
    memory lever for big models (activation footprint / microbatch);
  * remat: 'none' | 'full' | 'dots' activation checkpointing over the
    layer scan;
  * grad_sync: 'auto' leaves the gradient reduction to GSPMD (it fuses
    the reduce into the backward); 'compressed' runs the explicit
    int8-on-the-wire quantized circulant all-reduce with complete error
    feedback over the data-parallel axis (see optim/compression.py):
    gradients are bucketized over the comm pytree API, each bucket spec
    freezes exactly one quantized-allreduce plan reused every step via
    the process-wide plan cache, and the per-rank error-feedback buckets
    ride in the train state under ``state["gsync_err"]``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.transformer import init_params, loss_fn
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
from repro.optim.compression import (
    bucketize,
    compressed_grad_sync,
    init_grad_sync_state,
    make_bucket_spec,
    streamed_sync_params,
)


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "full"
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    grad_sync: str = "auto"          # auto | compressed
    dp_axes: Tuple[str, ...] = ("data",)
    # gradient-accumulation dtype: f32 default; bf16 halves the sharded
    # accumulator for capacity-constrained giants (deepseek-v3 on 256
    # chips) at ~3 bits of accumulation precision over 16 microbatches.
    grad_acc_dtype: str = "float32"
    # compressed grad-sync knobs (ignored for grad_sync='auto'): data
    # plane backend for the quantized circulant allreduce and the target
    # f32 payload per gradient bucket.
    grad_sync_backend: str = "jnp"   # jnp | pallas
    bucket_bytes: int = 4 << 20
    # stream the bucket sync: run each gradient bucket's quantized
    # allreduce inside the backward via per-bucket custom_vjp markers
    # (bucket k's collective overlaps the backward of the layers feeding
    # buckets k+1..) instead of syncing the materialized gradient after
    # the backward.  Ignored for grad_sync='auto'.
    stream_grad_sync: bool = False


def grad_bucket_spec(cfg: ModelConfig, tcfg: TrainConfig):
    """The frozen gradient BucketSpec for this model/config pair (from
    abstract parameter shapes; no allocation)."""
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    return make_bucket_spec(shapes, bucket_bytes=tcfg.bucket_bytes)


def _dp_size(tcfg: TrainConfig, mesh) -> int:
    if mesh is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in tcfg.dp_axes
                        if a in mesh.shape]))


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key, mesh=None):
    params = init_params(cfg, key)
    state = {"params": params, "opt": init_opt_state(tcfg.opt, params)}
    if tcfg.grad_sync == "compressed":
        spec = grad_bucket_spec(cfg, tcfg)
        state["gsync_err"] = init_grad_sync_state(spec, _dp_size(tcfg, mesh))
    return state


def train_state_shape(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """Abstract train state via eval_shape (no allocation; dry-run path)."""
    return jax.eval_shape(
        lambda k: init_train_state(cfg, tcfg, k, mesh=mesh),
        jax.random.PRNGKey(0),
    )


def _microbatch(batch: Dict[str, jnp.ndarray], n: int):
    """[B, ...] -> [n, B/n, ...] for scanning.  B is the global batch
    under GSPMD and the per-rank shard inside the compressed step's
    shard_map."""
    def split(x):
        gb = x.shape[0]
        assert gb % n == 0, f"batch dim {gb} % microbatches {n} != 0"
        return x.reshape((n, gb // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """Build the (state, batch) -> (state, metrics) step.

    ``grad_sync='auto'`` needs no mesh (GSPMD reduces gradients inside
    the jitted backward).  ``grad_sync='compressed'`` with a mesh whose
    data-parallel extent is > 1 wraps the step in shard_map over the dp
    axis and replaces the gradient reduction with the bucketized
    quantized circulant allreduce; with no mesh (or dp == 1) it
    degrades to the plain step, passing the (trivial) error state
    through unchanged so the state pytree structure is stable.
    """

    def compute_grads(params, batch):
        def loss_for(p, mb):
            loss, metrics = loss_fn(p, cfg, mb, remat=tcfg.remat)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_for, has_aux=True)

        if tcfg.microbatches > 1:
            mbs = _microbatch(batch, tcfg.microbatches)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g
                )
                return (g_acc, l_acc + loss), metrics

            acc_dt = (
                jnp.bfloat16 if tcfg.grad_acc_dtype == "bfloat16" else jnp.float32
            )
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (g_sum, loss_sum), metrics = jax.lax.scan(
                acc_step, (g0, jnp.float32(0)), mbs
            )
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, g_sum)
            loss = loss_sum / tcfg.microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def finish(params, opt, grads, loss, metrics):
        new_params, new_opt, opt_metrics = apply_updates(
            tcfg.opt, params, grads, opt
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    dp = _dp_size(tcfg, mesh)
    if tcfg.grad_sync == "compressed" and dp > 1:
        return _make_compressed_step(cfg, tcfg, mesh, dp,
                                     compute_grads, finish)

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        new_params, new_opt, metrics = finish(
            state["params"], state["opt"], grads, loss, metrics
        )
        new_state = {"params": new_params, "opt": new_opt}
        if "gsync_err" in state:
            # dp == 1: nothing to sync, error state is identically zero.
            new_state["gsync_err"] = state["gsync_err"]
        return new_state, metrics

    return train_step


def _make_compressed_step(cfg, tcfg, mesh, dp, compute_grads, finish):
    """shard_map'd train step with bucketized int8 circulant grad sync."""
    if len(tcfg.dp_axes) != 1:
        raise ValueError(
            "grad_sync='compressed' requires a single data-parallel axis; "
            f"got dp_axes={tcfg.dp_axes!r}"
        )
    axis = tcfg.dp_axes[0]
    other = {a: s for a, s in mesh.shape.items() if a != axis and s != 1}
    if other:
        raise ValueError(
            "grad_sync='compressed' supports pure data parallelism; "
            f"non-trivial mesh axes {other} present"
        )
    spec = grad_bucket_spec(cfg, tcfg)
    nb = spec.num_buckets

    from repro.core.jaxcompat import shard_map

    def body(params, opt, errs, batch):
        # Gradients stay local to the shard: the lossy sync below is the
        # only cross-rank reduction (GSPMD must not insert its own).
        loss, metrics, grads = compute_grads(params, batch)
        mean_grads, new_errs = compressed_grad_sync(
            grads, [e[0] for e in errs], axis, dp, spec,
            backend=tcfg.grad_sync_backend,
        )
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        # apply_updates is deterministic on identical (replicated)
        # inputs, so params/opt remain replicated without a broadcast.
        new_params, new_opt, metrics = finish(
            params, opt, mean_grads, loss, metrics
        )
        return new_params, new_opt, tuple(e[None] for e in new_errs), metrics

    def loss_for(p, mb):
        return loss_fn(p, cfg, mb, remat=tcfg.remat)

    nbm = tcfg.microbatches
    acc_dt = (jnp.bfloat16 if tcfg.grad_acc_dtype == "bfloat16"
              else jnp.float32)

    def streamed_body(params, opt, errs, batch):
        # Bucket streaming: the loss is computed THROUGH per-bucket sync
        # markers, so reverse-mode AD runs bucket k's quantized allreduce
        # the moment its cotangent is complete -- the collective has no
        # data dependence on the still-pending backward of the earlier
        # layers, and XLA overlaps the two.  With gradient accumulation,
        # the first nbm-1 microbatches accumulate raw local gradients
        # and only the final microbatch's backward streams the sync of
        # the accumulated total.
        err_flat = tuple(e[0] for e in errs)
        if nbm > 1:
            mbs = _microbatch(batch, nbm)
            lead = jax.tree.map(lambda x: x[:-1], mbs)
            last = jax.tree.map(lambda x: x[-1], mbs)
            grad_fn = jax.value_and_grad(loss_for, has_aux=True)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (loss, _metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda q: jnp.zeros(q.shape, acc_dt), params)
            (g_lead, loss_lead), _ = jax.lax.scan(
                acc_step, (g0, jnp.float32(0)), lead
            )
            acc_buckets = bucketize(g_lead, spec)
        else:
            last = batch
            loss_lead = jnp.float32(0)
            acc_buckets = [jnp.zeros((s,), jnp.float32)
                           for s in spec.bucket_sizes]

        def streamed_loss(ps, err_b, mb):
            synced = streamed_sync_params(
                ps, err_b, acc_buckets, spec, axis, dp,
                backend=tcfg.grad_sync_backend, accum_scale=1.0 / nbm,
            )
            return loss_for(synced, mb)

        ((loss, metrics), (mean_grads, new_errs)) = jax.value_and_grad(
            streamed_loss, argnums=(0, 1), has_aux=True
        )(params, err_flat, last)
        loss = jax.lax.pmean((loss_lead + loss) / nbm, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        new_params, new_opt, metrics = finish(
            params, opt, mean_grads, loss, metrics
        )
        return new_params, new_opt, tuple(e[None] for e in new_errs), metrics

    sharded_body = shard_map(
        streamed_body if tcfg.stream_grad_sync else body,
        mesh=mesh,
        in_specs=(P(), P(), (P(axis),) * nb, P(axis)),
        out_specs=(P(), P(), (P(axis),) * nb, P()),
        check_vma=False,
    )

    def train_step(state, batch):
        new_params, new_opt, new_errs, metrics = sharded_body(
            state["params"], state["opt"], tuple(state["gsync_err"]), batch
        )
        return (
            {"params": new_params, "opt": new_opt, "gsync_err": new_errs},
            metrics,
        )

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, remat="none")
        return loss

    return eval_step
