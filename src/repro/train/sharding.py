"""Parameter / batch / cache PartitionSpec rules for the production mesh.

Mesh axes: ('data', 'model') single-pod or ('pod', 'data', 'model')
multi-pod.  Batch shards over (pod, data); parameters are 2-D sharded:
the "model" (TP/EP) dimension over 'model' and the FSDP dimension over
(pod, data) -- ZeRO-3 style, XLA re-gathers per layer inside the scan.

Rules are name-based on the last path component with MoE-expert special
cases; stacked (scanned) parameters get a leading None axis.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path, DictKey

from repro.models.common import ModelConfig


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (dp_axes, model_axis) for a production mesh."""
    names = mesh.axis_names
    model = "model" if "model" in names else names[-1]
    dp = tuple(n for n in names if n != model)
    return dp, model


# base-ndim rules: name -> (base_ndim, spec builder)
def _rules(dp, model):
    fs = dp if (isinstance(dp, tuple) and len(dp) > 1) else (
        dp[0] if dp else None)
    return {
        # [in, out] column-parallel
        "wq": (2, P(fs, model)),
        "wk": (2, P(fs, model)),
        "wv": (2, P(fs, model)),
        "w_gate": (2, P(fs, model)),
        "w_up": (2, P(fs, model)),
        "w_in": (2, P(fs, model)),
        "in_proj": (2, P(fs, model)),
        "w_dq": (2, P(fs, model)),
        "w_uq": (2, P(fs, model)),
        "w_dkv": (2, P(fs, None)),
        "w_uk": (2, P(None, model)),
        "w_uv": (2, P(None, model)),
        "w_kr": (2, P(fs, None)),
        "img_proj": (2, P(fs, model)),
        "mtp_proj": (2, P(fs, model)),
        # [in, out] row-parallel
        "wo": (2, P(model, fs)),
        "w_down": (2, P(model, fs)),
        "w_out": (2, P(model, fs)),
        "out_proj": (2, P(model, fs)),
        # embeddings: vocab over model, d over fsdp
        "embed": (2, P(model, fs)),
        "unembed": (2, P(model, fs)),
        # biases follow the sharded output dim
        "bq": (1, P(model)),
        "bk": (1, P(model)),
        "bv": (1, P(model)),
        # ssm conv
        "conv_w": (2, P(None, model)),
        "conv_b": (1, P(model)),
        # router: small, replicated
        "router": (2, P(None, None)),
    }


EP_MODE = "2d"  # "2d": E over model + FFN dim over fsdp (ZeRO-3 style)
                # "full": E over (data x model) -- experts fully local,
                # no per-microbatch expert re-gather; dispatch becomes an
                # all-to-all (the DeepSeek-V3 EP design)


def set_ep_mode(mode: str):
    global EP_MODE
    assert mode in ("2d", "full")
    EP_MODE = mode


def ep_axes(mesh: Mesh):
    """Expert-sharding axes under EP_MODE='full': (data, model) --
    'pod' (if present) shards the expert d dim instead (E=256 does not
    divide 512)."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("data", "model"))


def _moe_expert_specs(dp, model, mesh: Mesh):
    if EP_MODE == "full":
        ea = ep_axes(mesh)
        pod = "pod" if "pod" in mesh.axis_names else None
        return {
            "w_gate": P(ea, pod, None),
            "w_up": P(ea, pod, None),
            "w_down": P(ea, None, pod),
        }
    return {
        "w_gate": P(model, None, dp),
        "w_up": P(model, None, dp),
        "w_down": P(model, dp, None),
    }


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        else:
            out.append(str(k))
    return tuple(out)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes whose mesh size does not divide the dimension.

    pjit *arguments* require exact divisibility (unlike internal
    constraints, which pad); odd vocabularies (49155, 50280, 51865) and
    batch=1 cells would otherwise fail to lower.  Dropping the axis
    replicates that dim -- correct, at some memory cost (DESIGN.md
    notes vocab padding as the production alternative)."""
    fitted = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            fitted.append(None if i >= len(shape) else ax)
            continue
        fitted.append(ax if shape[i] % _axis_size(mesh, ax) == 0 else None)
    return P(*fitted)


def param_pspecs(cfg: ModelConfig, params_shape: Any, mesh: Mesh,
                 no_fsdp: bool = False):
    """PartitionSpec pytree matching a params (shape) pytree.

    no_fsdp=True replicates parameters over the dp axes (inference: no
    optimizer state, so ZeRO-style dp-sharding only buys a per-step
    weight all-gather -- measured ~2 GB/token on stablelm decode)."""
    dp, model = mesh_axes(mesh)
    fs = None if no_fsdp else (dp if len(dp) > 1 else (dp[0] if dp else None))
    rules = _rules(() if no_fsdp else dp, model)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        ndim = len(leaf.shape)
        in_moe = any("moe" in n for n in names) and not any(
            n == "shared" for n in names
        )
        moe_specs = _moe_expert_specs(fs, model, mesh)
        if in_moe and name in moe_specs and ndim >= 3:
            base = moe_specs[name]
            extra = ndim - 3
            return fit_spec(P(*([None] * extra + list(base))), leaf.shape, mesh)
        if name in rules:
            base_ndim, base = rules[name]
            extra = ndim - base_ndim
            if extra < 0:
                return P()
            return fit_spec(P(*([None] * extra + list(base))), leaf.shape, mesh)
        return P()  # norms, scalars, A_log, D, dt_bias, gate ...

    return tree_map_with_path(spec_for, params_shape)


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shape: Dict[str, Any]):
    dp, model = mesh_axes(mesh)
    fs = dp if len(dp) > 1 else dp[0]
    out = {}
    for k, v in batch_shape.items():
        nd = len(v.shape)
        out[k] = fit_spec(P(*([fs] + [None] * (nd - 1))), v.shape, mesh)
    return out


CACHE_SEQ_SHARD = True  # False: batch-only sharding (replicate S over
                        # model) when kv heads don't divide the axis


def set_cache_seq_shard(flag: bool):
    global CACHE_SEQ_SHARD
    CACHE_SEQ_SHARD = flag


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shape: Dict[str, Any]):
    """Decode-cache sharding: batch over dp where possible; the sequence
    dim of attention caches over 'model' when kv-heads don't divide the
    model axis (flash-decode style; softmax reductions over S become the
    psum GSPMD inserts), else heads over 'model'."""
    dp, model = mesh_axes(mesh)
    fs = dp if len(dp) > 1 else dp[0]
    msize = mesh.shape[model]
    out = {}
    for k, v in cache_shape.items():
        nd = len(v.shape)
        if k == "pos_idx":
            out[k] = P(fs)  # per-slot positions, batch-sharded
        elif k == "memory":
            out[k] = P(fs, None, None)
        elif k.endswith("_k") or k.endswith("_v"):
            # [R, B, S, Hkv, hd]
            hkv = v.shape[3]
            if hkv % msize == 0:
                out[k] = P(None, fs, None, model, None)
            elif CACHE_SEQ_SHARD:
                out[k] = P(None, fs, model, None, None)
            else:
                out[k] = P(None, fs, None, None, None)
        elif k.endswith("_ckv") or k.endswith("_kr"):
            # [R, B, S, r] (MLA compressed cache): seq over model
            if CACHE_SEQ_SHARD:
                out[k] = P(None, fs, model, None)
            else:
                out[k] = P(None, fs, None, None)
        elif k.endswith("_conv"):
            out[k] = P(None, fs, None, model)
        elif k.endswith("_ssd"):
            # [R, B, H, N, P]: heads over model
            out[k] = P(None, fs, model, None, None)
        else:
            out[k] = P(*([None] * nd))
        out[k] = fit_spec(out[k], v.shape, mesh)
    return out


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
