"""Checkpoint-restore fan-out via the paper's n-block circulant broadcast.

At fleet scale only one host (or a small reader group) reads the
checkpoint from storage; the state must then be broadcast to all
data-parallel replicas.  This module does that with the plan/execute
communicator (:mod:`repro.core.comm`): leaves are packed per dtype
into one flat message each, so the per-round message count is the
number of distinct dtypes (typically 1-3), not the leaf count
(hundreds), and the whole checkpoint rides ONE shared schedule with
the alpha-beta-optimal number of blocks n*, pipelined in
n-1+ceil(log2 p) ppermute rounds -- the exact Algorithm-1 use case the
paper targets (their MPI_Bcast), including the O(log p) schedule
recomputation that makes *elastic* restores (p changed since the last
run) cheap.  Leaves keep their dtypes (no flatten-to-float32 detour),
and repeated restores with the same state spec reuse one cached
CollectivePlan.

``broadcast_state`` is mesh-axis-generic: pass the dp axis of the
production mesh; TP/model shards are read per-host as usual.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.comm import get_comm
from repro.core.costmodel import CommModel, optimal_num_blocks_bcast
from repro.core.engine import get_bundle


def restore_plan(p: int, nbytes: int, *, root: int = 0,
                 model: CommModel = CommModel(alpha=2e-6, beta=1.0 / 25e9),
                 n_blocks: Optional[int] = None):
    """Host-side plan for a restore fan-out: (bundle, n, rounds).

    Computes the alpha-beta-optimal block count n* for the checkpoint
    size and pre-warms the process-wide schedule cache for ``(p, root)``
    -- on an elastic restore (p changed since the last run) this is the
    only schedule work, O(p log p) once, before any device code runs.
    """
    bundle = get_bundle(p, root)
    n = n_blocks or max(1, optimal_num_blocks_bcast(p, nbytes, model))
    return bundle, n, bundle.rounds(n)


def broadcast_state(
    mesh: Mesh,
    axis_name: str,
    state: Any,
    *,
    root: int = 0,
    model: CommModel = CommModel(alpha=2e-6, beta=1.0 / 25e9),  # DCN-ish
    n_blocks: Optional[int] = None,
):
    """Broadcast a state pytree from ``root``'s slice along ``axis_name``.

    ``state`` leaves must carry a leading axis of size p (one slice per
    rank, only root's content meaningful -- the natural layout after a
    single-reader restore).  Returns the pytree with every slice equal
    to the root's.  Leaves are concatenated per dtype into one flat
    [p, total] message each before the broadcast, so the per-round
    latency term is ``#dtypes * alpha`` rather than ``#leaves * alpha``
    while every leaf still comes back in its own dtype; the packed tree
    rides ONE shared schedule (one cached
    :class:`repro.core.comm.CollectivePlan`), so the pipeline depth n*
    amortizes across the whole checkpoint.
    """
    p = mesh.shape[axis_name]
    leaves, treedef = jax.tree.flatten(state)
    groups: dict = {}                       # dtype name -> leaf indices
    for i, leaf in enumerate(leaves):
        assert leaf.shape[0] == p, "leaves need a leading per-rank axis"
        groups.setdefault(str(leaf.dtype), []).append(i)
    packed = {
        key: jnp.concatenate([jnp.reshape(leaves[i], (p, -1)) for i in idxs],
                             axis=1)
        for key, idxs in groups.items()
    }
    comm = get_comm(mesh, axis_name, model=model)
    out = comm.broadcast(packed, n_blocks=n_blocks, root=root)
    outs: list = [None] * len(leaves)
    for key, idxs in groups.items():
        off = 0
        for i in idxs:
            size = int(np.prod(leaves[i].shape[1:], dtype=np.int64))
            outs[i] = out[key][:, off: off + size].reshape(leaves[i].shape)
            off += size
    return jax.tree.unflatten(treedef, outs)
