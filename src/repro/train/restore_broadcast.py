"""Checkpoint-restore fan-out via the paper's n-block circulant broadcast.

At fleet scale only one host (or a small reader group) reads the
checkpoint from storage; the state must then be broadcast to all
data-parallel replicas.  This module does that with
``core.collectives.circulant_broadcast``: the flattened state is split
into the alpha-beta-optimal number of blocks n* and pipelined in
n-1+ceil(log2 p) ppermute rounds -- the exact Algorithm-1 use case the
paper targets (their MPI_Bcast), including the O(log p) schedule
recomputation that makes *elastic* restores (p changed since the last
run) cheap.

``broadcast_state`` is mesh-axis-generic: pass the dp axis of the
production mesh; TP/model shards are read per-host as usual.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.collectives import circulant_broadcast
from repro.core.costmodel import CommModel, optimal_num_blocks_bcast
from repro.core.engine import get_bundle


def restore_plan(p: int, nbytes: int, *, root: int = 0,
                 model: CommModel = CommModel(alpha=2e-6, beta=1.0 / 25e9),
                 n_blocks: Optional[int] = None):
    """Host-side plan for a restore fan-out: (bundle, n, rounds).

    Computes the alpha-beta-optimal block count n* for the checkpoint
    size and pre-warms the process-wide schedule cache for ``(p, root)``
    -- on an elastic restore (p changed since the last run) this is the
    only schedule work, O(p log p) once, before any device code runs.
    """
    bundle = get_bundle(p, root)
    n = n_blocks or max(1, optimal_num_blocks_bcast(p, nbytes, model))
    return bundle, n, bundle.rounds(n)


def broadcast_state(
    mesh: Mesh,
    axis_name: str,
    state: Any,
    *,
    root: int = 0,
    model: CommModel = CommModel(alpha=2e-6, beta=1.0 / 25e9),  # DCN-ish
    n_blocks: Optional[int] = None,
):
    """Broadcast a state pytree from ``root``'s slice along ``axis_name``.

    ``state`` leaves must carry a leading axis of size p (one slice per
    rank, only root's content meaningful -- the natural layout after a
    single-reader restore).  Returns the pytree with every slice equal to
    the root's.  Leaves are flattened into ONE message so the pipeline
    depth n* amortizes across the whole checkpoint.
    """
    p = mesh.shape[axis_name]
    leaves, treedef = jax.tree.flatten(state)
    flats = []
    shapes = []
    for leaf in leaves:
        assert leaf.shape[0] == p, "leaves need a leading per-rank axis"
        shapes.append(leaf.shape)
        flats.append(leaf.reshape(p, -1).astype(jnp.float32))
    sizes = [f.shape[1] for f in flats]
    big = jnp.concatenate(flats, axis=1)                      # [p, total]
    nbytes = big.shape[1] * 4
    _, n, _ = restore_plan(p, nbytes, root=root, model=model, n_blocks=n_blocks)
    out = circulant_broadcast(mesh, axis_name, big, n_blocks=n, root=root)
    outs = []
    off = 0
    for shape, size, leaf in zip(shapes, sizes, leaves):
        piece = out[:, off : off + size].astype(leaf.dtype).reshape(shape)
        outs.append(piece)
        off += size
    return jax.tree.unflatten(treedef, outs)
