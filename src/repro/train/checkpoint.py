"""Fault-tolerant checkpointing: atomic, versioned, keep-k, elastic.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json ; a checkpoint becomes
visible only after an atomic rename of its temp directory, so a crash
mid-save never corrupts the restore path.  ``restore_latest`` picks the
newest complete checkpoint (torn ones are ignored and garbage-collected).

Elastic restarts: checkpoints store *global* (unsharded) arrays, so a
restore onto a different mesh/process-count just re-shards at device_put
time -- combined with the O(log p) schedule recomputation of the paper's
collectives this makes mesh-resize restarts cheap: new p => new schedule
tables in O(log p) per rank, no O(p log^2 p) stall (the paper's original
motivation for fast schedule construction).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        arr = leaf
        if hasattr(arr, "dtype") and str(arr.dtype) == "bfloat16":
            # numpy has no bf16; store as f32 (lossless), the restore path
            # casts back to the template leaf's dtype
            import jax.numpy as jnp

            arr = jnp.asarray(arr).astype(jnp.float32)
        flat[key] = np.asarray(arr)
    return flat


def _unflatten_into(tree_like, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             block: bool = False):
        """Snapshot state (pytree) at step.  Device arrays are fetched
        synchronously (cheap host copy); the disk write happens on a
        background thread unless block=True."""
        flat = _flatten(state)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "keys": sorted(flat.keys()),
        }
        if self._thread is not None:
            self._thread.join()  # one outstanding async save at a time

        def _write():
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_save_")
            try:
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                final = os.path.join(self.dir, f"step_{step:010d}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)  # atomic publish
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)
        # clean torn temp dirs older than 1h
        for name in os.listdir(self.dir):
            if name.startswith(".tmp_save_"):
                p = os.path.join(self.dir, name)
                if time.time() - os.path.getmtime(p) > 3600:
                    shutil.rmtree(p, ignore_errors=True)

    # ---------------------------------------------------------- restore

    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "manifest.json")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore_latest(self, state_like: Any) -> Tuple[Optional[int], Any, Dict]:
        """Returns (step, state, extra) or (None, state_like, {})."""
        steps = self.list_steps()
        if not steps:
            return None, state_like, {}
        step = steps[-1]
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = dict(np.load(os.path.join(path, "arrays.npz")))
        state = _unflatten_into(state_like, flat)
        return step, state, manifest.get("extra", {})
