"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state -- the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init and
only then calls this.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)  # pre-AxisType jax (< 0.5)


# The mesh context entered by the pre-0.6 fallback below; exited before a
# replacement is entered so repeated calls (e.g. dry-run sweeps) don't
# stack leaked contexts.
_ACTIVE_MESH_CTX = []


def set_global_mesh(mesh):
    """jax.sharding.set_mesh across jax versions.

    ``set_mesh`` only exists from jax 0.6; on older versions entering the
    mesh context manager (kept open until the next call or process exit)
    provides the ambient mesh.
    """
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        setter(mesh)
    else:
        while _ACTIVE_MESH_CTX:
            _ACTIVE_MESH_CTX.pop().__exit__(None, None, None)
        mesh.__enter__()
        _ACTIVE_MESH_CTX.append(mesh)
    return mesh


def make_host_mesh(p: int, axis: str = "data"):
    """Small host-device mesh for tests/benchmarks."""
    import numpy as np

    return jax.sharding.Mesh(np.array(jax.devices()[:p]), (axis,))
