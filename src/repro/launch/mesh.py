"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state -- the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init and
only then calls this.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(p: int, axis: str = "data"):
    """Small host-device mesh for tests/benchmarks."""
    import numpy as np

    return jax.sharding.Mesh(np.array(jax.devices()[:p]), (axis,))
