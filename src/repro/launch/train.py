"""Production training launcher: mesh + sharded state + fault tolerance.

    # real pod (or host-device simulation of one):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-0.5b --smoke --mesh 4x2 --steps 20

Assembles every substrate layer on an explicit (data, model) mesh:
sharded train state (ZeRO-3 + TP rules from train/sharding.py), the
deterministic data pipeline sharded over the data axis, jit with
in/out shardings and state donation, checkpoint/auto-resume, and the
paper's circulant broadcast for the restore fan-out when more than one
data shard participates.
"""

import os
import sys

if __name__ == "__main__" and "--devices" in sys.argv:
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ.setdefault("XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")

import argparse
import math
import time

import jax

from repro.launch.mesh import set_global_mesh
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import hints
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.sharding import batch_pspecs, mesh_axes, named, param_pspecs
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def build_mesh(spec: str) -> Mesh:
    dims = [int(x) for x in spec.split("x")]
    devs = jax.devices()
    need = int(np.prod(dims))
    assert len(devs) >= need, f"need {need} devices, have {len(devs)}"
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return Mesh(np.array(devs[:need]).reshape(dims), names)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--mesh", default="2x2", help="e.g. 4x2 = data4 x model2")
    ap.add_argument("--devices", default=None, help="host device count")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--grad-sync", default="auto",
                    choices=("auto", "compressed"),
                    help="'compressed' = int8 quantized circulant "
                         "allreduce with error feedback (pure-dp mesh)")
    ap.add_argument("--grad-sync-backend", default="jnp",
                    choices=("jnp", "pallas"))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    mesh = build_mesh(args.mesh)
    dp_axes, model_axis = mesh_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    set_global_mesh(mesh)
    if args.grad_sync == "auto":
        # GSPMD layout hints.  The compressed path runs the model inside
        # shard_map (every mesh axis manual), where sharding constraints
        # are both illegal and pointless -- shards are explicit already.
        hints.set_hint("hidden", P(dp_axes, None, None))
        hints.set_hint("logits", P(dp_axes, None, model_axis))
    print(f"mesh {dict(mesh.shape)}  dp={dp}")

    cfg = get_config(args.arch, smoke=args.smoke)
    microbatches = args.microbatches
    if args.grad_sync == "compressed":
        # The compressed step microbatches the per-rank shard (the model
        # runs inside shard_map), so the split must divide batch/dp.
        local = max(1, args.global_batch // dp)
        microbatches = math.gcd(microbatches, local)
        if microbatches != args.microbatches:
            print(f"grad-sync=compressed: microbatches "
                  f"{args.microbatches} -> {microbatches} "
                  f"(must divide per-rank batch {local})")
    tcfg = TrainConfig(
        microbatches=microbatches, remat="full",
        opt=AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps),
        dp_axes=dp_axes,
        grad_sync=args.grad_sync,
        grad_sync_backend=args.grad_sync_backend,
    )
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    # sharded state
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0), mesh=mesh)
    pspecs = param_pspecs(cfg, state["params"], mesh)
    state_specs = {"params": pspecs,
                   "opt": {"mu": pspecs, "nu": pspecs, "step": P()}}
    if "gsync_err" in state:
        # error-feedback buckets: [dp, bucket] rows, one per dp shard
        state_specs["gsync_err"] = tuple(
            P(dp_axes) for _ in state["gsync_err"])
    state = jax.device_put(state, named(mesh, state_specs))

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.global_batch))
    bshapes = data.batch_at(0)
    bspecs = batch_pspecs(cfg, mesh, {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in bshapes.items()
    })
    bnamed = named(mesh, bspecs)

    # Pin the output state to the same ZeRO-3/TP specs as the input:
    # without out_shardings GSPMD may pick a different layout for some
    # leaves after step 1, which then mismatches in_shardings (and
    # silently drifts the state layout on any jax version).
    step_fn = jax.jit(
        make_train_step(cfg, tcfg, mesh=mesh),
        in_shardings=(named(mesh, state_specs), bnamed),
        out_shardings=(named(mesh, state_specs), None),
        donate_argnums=(0,),
    )

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start, state_restored, extra = mgr.restore_latest(
        jax.tree.map(np.asarray, state))
    t0_step = 0
    if start is not None:
        state = jax.device_put(state_restored, named(mesh, state_specs))
        t0_step = int(extra.get("data_step", 0))
        print(f"resumed from step {start}")

    t0 = time.time()
    for i in range(t0_step, args.steps):
        batch = jax.device_put(data.batch_at(i), bnamed)
        state, m = step_fn(state, batch)
        if (i + 1) % 5 == 0:
            print(f"step {i+1:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, jax.tree.map(np.asarray, state),
                     extra={"data_step": i + 1})
    mgr.wait()
    dt = time.time() - t0
    print(f"done: {args.steps - t0_step} steps in {dt:.1f}s "
          f"({dt/max(args.steps-t0_step,1)*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
