import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh with ShapeDtypeStruct stand-ins
(no device allocation), and record the roofline inputs:

    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
    python -m repro.launch.dryrun --arch all --shape all --mesh both --subproc

Per cell this prints/saves:
  * compiled.memory_analysis()   -- proves the cell fits per-device HBM,
  * compiled.cost_analysis()     -- per-device HLO FLOPs / bytes accessed,
  * parsed collective stats      -- per-device collective bytes + rounds,
  * derived roofline terms (see repro/launch/roofline.py).

NOTE: the XLA_FLAGS line above must execute before ANY jax import (jax
locks the device count on first init); keep it the first statement.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_arch_names, get_config
from repro.launch.hlo_analysis import collective_stats, weighted_cost
from repro.launch.mesh import make_production_mesh, set_global_mesh
from repro.models.common import SHAPES, ModelConfig, ShapeConfig
from repro.models import moe as moe_mod
from repro.models.transformer import init_cache
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.sharding import batch_pspecs, cache_pspecs, mesh_axes, named, param_pspecs
from repro.train.trainer import TrainConfig, make_train_step, train_state_shape

from jax.sharding import NamedSharding, PartitionSpec as P

# long_500k requires sub-quadratic attention: run for ssm/hybrid/SWA archs.
LONG_OK = {"zamba2-2.7b", "mamba2-780m", "h2o-danube-1.8b"}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, dp: int) -> int:
    if shape.kind != "train":
        return 1
    per_dev = max(1, shape.global_batch // dp)
    if cfg.d_model >= 4096 or cfg.moe is not None:
        target = 1
    elif cfg.d_model >= 2048:
        target = 2
    else:
        target = 4
    return max(1, per_dev // target)


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig):
    gb, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((gb, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((gb, s), jnp.int32),
    }
    if cfg.family == "vlm":
        out["memory_embeds"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_image_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        out["memory_embeds"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_audio_frames, cfg.d_model), jnp.float32
        )
    return out


def input_specs(arch: str, shape_name: str):
    """Public helper: ShapeDtypeStruct stand-ins for every model input of
    the given cell (the dry-run contract)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return batch_shapes(cfg, shape)
    cache = jax.eval_shape(
        lambda: init_cache(
            cfg, shape.global_batch, shape.seq_len,
            memory=_memory_shape(cfg, shape),
        )
    )
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "cache": cache,
    }


def _memory_shape(cfg, shape):
    if cfg.family == "vlm":
        return jnp.zeros((shape.global_batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        return jnp.zeros((shape.global_batch, cfg.n_audio_frames, cfg.d_model), jnp.float32)
    return None


def _infer_no_fsdp(cfg: ModelConfig, mesh, model_axis: str) -> bool:
    """Replicate inference weights over dp only when the TP-sharded copy
    is small (<= 2 GB/device) and the model is not MoE (expert weights
    dominate HBM; deepseek-v3's 84 GB/device copy obviously cannot be
    replicated).  Saves ~2 GB/token of ZeRO-3 weight re-gather on the
    cells where it fits (EXPERIMENTS.md Perf D2)."""
    if os.environ.get("DRYRUN_INFER_NO_FSDP", "1") != "1":
        return False
    per_dev = cfg.param_count() * 2 / mesh.shape[model_axis]
    return per_dev <= 2e9 and cfg.moe is None


def lower_cell(arch: str, shape_name: str, multi_pod: bool, microbatches=None,
               remat: str = "full", extra_tag: str = ""):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes, model_axis = mesh_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    set_global_mesh(mesh)
    from repro.train import sharding as shard_rules
    ep_mode = os.environ.get("DRYRUN_EP_MODE", "2d")
    shard_rules.set_ep_mode(ep_mode)
    shard_rules.set_cache_seq_shard(
        os.environ.get("DRYRUN_CACHE_SEQ_SHARD", "1") == "1")
    if ep_mode == "full":
        moe_mod.set_default_ep_spec(P(shard_rules.ep_axes(mesh), None, None))
    else:
        moe_mod.set_default_ep_spec(P(model_axis, None, None))
    from repro.models import hints
    hints.set_hint("hidden", P(dp_axes, None, None))
    hints.set_hint("logits", P(dp_axes, None, model_axis))
    if os.environ.get("DRYRUN_ATTN_SHARD", "1") == "1":
        # q heads over 'model' (GSPMD pads uneven counts); kv heads only
        # when they divide the axis -- padding 8 kv heads to 16 shards
        # was measured 5x WORSE on stablelm (see EXPERIMENTS.md Perf C1),
        # replicated kv heads are tiny and keep scores fully local.
        msize = mesh.shape[model_axis]
        hints.set_hint("attn_q", P(dp_axes, None, model_axis, None))
        kv_ok = cfg.n_kv_heads and cfg.n_kv_heads % msize == 0
        hints.set_hint(
            "attn_kv",
            P(dp_axes, None, model_axis if kv_ok else None, None),
        )

    if shape.name == "long_500k" and arch not in LONG_OK:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "skipped": f"{arch} is full-attention; long_500k requires "
            "sub-quadratic attention (see DESIGN.md)",
        }

    mb = microbatches or default_microbatches(cfg, shape, dp)
    t0 = time.time()

    if shape.kind == "train":
        big = cfg.param_count() > 5e10
        tcfg = TrainConfig(
            microbatches=mb, remat=remat,
            opt=AdamWConfig(moment_dtype="bfloat16" if big else "float32"),
            grad_acc_dtype="bfloat16" if big else "float32",
            dp_axes=dp_axes,
        )
        state_shape = train_state_shape(cfg, tcfg)
        pspecs = param_pspecs(cfg, state_shape["params"], mesh)
        state_specs = {
            "params": pspecs,
            "opt": {"mu": pspecs, "nu": pspecs, "step": P()},
        }
        bshapes = batch_shapes(cfg, shape)
        bspecs = batch_pspecs(cfg, mesh, bshapes)
        step = make_train_step(cfg, tcfg)
        jitted = jax.jit(
            step,
            in_shardings=(named(mesh, state_specs), named(mesh, bspecs)),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_shape, bshapes)
    elif shape.kind == "prefill":
        no_fsdp = _infer_no_fsdp(cfg, mesh, model_axis)
        state_shape = jax.eval_shape(
            lambda k: __import__("repro.models.transformer", fromlist=["init_params"]).init_params(cfg, k),
            jax.random.PRNGKey(0),
        )
        pspecs = param_pspecs(cfg, state_shape, mesh, no_fsdp=no_fsdp)
        bshapes = batch_shapes(cfg, shape)
        bspecs = batch_pspecs(cfg, mesh, bshapes)
        pre = make_prefill_step(cfg)

        def prefill_fn(params, tokens, memory_embeds=None):
            return pre(params, tokens, memory_embeds)

        args = [state_shape, bshapes["tokens"]]
        in_sh = [named(mesh, pspecs), named(mesh, bspecs["tokens"])]
        if "memory_embeds" in bshapes:
            args.append(bshapes["memory_embeds"])
            in_sh.append(named(mesh, bspecs["memory_embeds"]))
        jitted = jax.jit(prefill_fn, in_shardings=tuple(in_sh))
        lowered = jitted.lower(*args)
    else:  # decode
        from repro.models.transformer import init_params

        no_fsdp = _infer_no_fsdp(cfg, mesh, model_axis)
        state_shape = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        pspecs = param_pspecs(cfg, state_shape, mesh, no_fsdp=no_fsdp)
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               memory=_memory_shape(cfg, shape))
        )
        cspecs = cache_pspecs(cfg, mesh, cache_shape)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_spec = P(dp_axes if shape.global_batch >= dp else None, None)
        dec = make_decode_step(cfg)
        jitted = jax.jit(
            dec,
            in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                          NamedSharding(mesh, tok_spec)),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(state_shape, cache_shape, tok)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_stats(txt)
    wc = weighted_cost(txt)

    # analytic model flops for the "useful compute" ratio
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    passes = 6 if shape.kind == "train" else 2
    model_flops_per_dev = passes * n_active * tokens / int(
        np.prod(list(mesh.shape.values()))
    )

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "tag": extra_tag,
        "microbatches": mb,
        "remat": remat,
        "devices": int(np.prod(list(mesh.shape.values()))),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "flops_weighted": float(wc["flops_weighted"]),
        "bytes_weighted": float(wc["bytes_weighted"]),
        "model_flops_per_device": float(model_flops_per_dev),
        "params_total": int(cfg.param_count()),
        "params_active": int(n_active),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        },
        **coll.as_dict(),
    }
    return rec


def cell_path(arch, shape, meshkind, tag=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    sfx = f"_{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{meshkind}{sfx}.json")


def run_cell(arch, shape, meshkind, microbatches=None, remat="full", tag=""):
    rec = lower_cell(arch, shape, meshkind == "multi", microbatches, remat, tag)
    path = cell_path(arch, shape, meshkind, tag)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--tag", default="")
    ap.add_argument("--subproc", action="store_true",
                    help="one subprocess per cell (fresh XLA heap)")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = all_arch_names() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for meshkind in meshes:
                if args.skip_done and os.path.exists(cell_path(arch, shape, meshkind, args.tag)):
                    print(f"skip done: {arch} {shape} {meshkind}")
                    continue
                print(f"=== {arch} x {shape} x {meshkind} ===", flush=True)
                if args.subproc:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", meshkind,
                           "--remat", args.remat]
                    if args.microbatches:
                        cmd += ["--microbatches", str(args.microbatches)]
                    if args.tag:
                        cmd += ["--tag", args.tag]
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, meshkind))
                else:
                    try:
                        run_cell(arch, shape, meshkind, args.microbatches,
                                 args.remat, args.tag)
                    except Exception:
                        traceback.print_exc()
                        failures.append((arch, shape, meshkind))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
