"""Collective-traffic extraction from compiled (post-SPMD) HLO text.

``cost_analysis`` gives per-device FLOPs and memory bytes but no
collective volume, so the roofline's collective term is derived here by
parsing the compiled module:

  * every all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute op contributes its *result-shape* bytes
    (per-device wire volume approximation);
  * ops inside `while` bodies (jax.lax.scan over layers / microbatches)
    are multiplied by the loop trip count, recovered from the loop
    condition's `compare(.., constant(N)), direction=LT`;
  * op count x trips is also reported as "rounds" -- the latency metric
    the paper's n-1+ceil(log2 p) bound speaks to.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"^\(?([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'f32[4,8]' or tuple '(f32[4], bf16[2,2])'."""
    total = 0
    for m in re.finditer(r"([a-z0-9_]+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    ops_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_rounds(self) -> int:
        return sum(self.ops_by_kind.values())

    def as_dict(self):
        return {
            "collective_bytes": self.total_bytes,
            "collective_rounds": self.total_rounds,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "ops_by_kind": dict(self.ops_by_kind),
        }


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", line)
        if m and ("(" in line and ")" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _find_entry(text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1) if m else ""


def _constants(lines: List[str]) -> Dict[str, int]:
    out = {}
    for l in lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)", l)
        if m:
            out[m.group(1)] = int(m.group(2))
    return out


_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _trip_from_line(while_line: str) -> int:
    """XLA annotates static loops: backend_config={"known_trip_count":{"n":N}}."""
    m = _TRIP_RE.search(while_line)
    return int(m.group(1)) if m else 0


def _trip_count(cond_lines: List[str], all_consts: Dict[str, int]) -> int:
    consts = dict(all_consts)
    consts.update(_constants(cond_lines))
    for l in cond_lines:
        m = re.search(
            r"compare\(\s*%?([\w.\-]+),\s*%?([\w.\-]+)\s*\),\s*direction=LT", l
        )
        if m:
            for name in (m.group(2), m.group(1)):
                if name in consts:
                    return consts[name]
    return 1


_DOT_RE = re.compile(
    r"=\s*([a-z0-9_]+)\[([0-9,]*)\][^=]*?\bdot\(\s*%?([\w.\-]+)\s*,"
)
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\S+?)\s+[a-z]")
_PARAM_SIG_RE = re.compile(r"%?([\w.\-]+):\s*([a-z0-9_]+\[[0-9,]*\])")


def _numel(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _comp_shapes(header: str, lines: List[str]) -> Dict[str, str]:
    """name -> shape-string map for one computation (params + op defs)."""
    shapes: Dict[str, str] = {}
    for m in _PARAM_SIG_RE.finditer(header):
        shapes[m.group(1)] = m.group(2)
    for l in lines:
        d = _DEF_RE.match(l)
        if d:
            shapes[d.group(1)] = d.group(2)
    return shapes


def _dot_flops(line: str, shapes: Dict[str, str]) -> int:
    m = _DOT_RE.search(line)
    if not m:
        return 0
    out_elems = _numel(m.group(2))
    lhs = shapes.get(m.group(3), "")
    sm = re.match(r"[a-z0-9_]+\[([0-9,]*)\]", lhs)
    if not sm:
        return 0
    lhs_dims = [int(x) for x in sm.group(1).split(",")] if sm.group(1) else []
    cm = _LHS_CONTRACT_RE.search(line)
    k = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2 * out_elems * k


def weighted_cost(hlo_text: str) -> Dict[str, float]:
    """Loop-corrected per-device costs parsed from compiled HLO text.

    XLA's cost_analysis() counts while bodies ONCE; this walks the call
    graph multiplying by trip counts (layer scans, microbatch scans):
      * flops: dot ops only (elementwise is noise at model scale),
      * bytes: 2x the result bytes of every materializing op (one write
        + amortized one read) -- an HBM-traffic estimate consistent
        across cells.
    """
    comps_raw: Dict[str, Tuple[str, List[str]]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(\([^{]*\))?.*\{\s*$", line)
        if m and "(" in line:
            cur = m.group(1)
            comps_raw[cur] = (line, [])
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps_raw[cur][1].append(line)

    entry = _find_entry(hlo_text)
    global_consts: Dict[str, int] = {}
    for _, lines in comps_raw.values():
        global_consts.update(_constants(lines))

    _MATERIALIZE = re.compile(
        r"=\s*(\S+?)\s+(fusion|dot|custom-call|copy|convolution|scatter|gather|"
        r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
        r"dynamic-update-slice|reduce|sort|select-and-scatter)\("
    )

    own_flops: Dict[str, int] = {}
    own_bytes: Dict[str, int] = {}
    calls: Dict[str, List[Tuple[str, int]]] = {}
    for name, (header, lines) in comps_raw.items():
        shapes = _comp_shapes(header, lines)
        fl = 0
        by = 0
        calls[name] = []
        for l in lines:
            fl += _dot_flops(l, shapes)
            mm = _MATERIALIZE.search(l)
            if mm:
                op = mm.group(2)
                if op in ("dynamic-update-slice", "scatter"):
                    # in-place update: traffic is the update operand, not
                    # the whole buffer (XLA aliases the result)
                    ops_m = re.search(
                        r"(?:dynamic-update-slice|scatter)\(([^)]*)\)", l
                    )
                    upd_bytes = 0
                    if ops_m:
                        names = [
                            o.strip().lstrip("%")
                            for o in ops_m.group(1).split(",")
                        ]
                        idx = 1 if op == "dynamic-update-slice" else 2
                        if len(names) > idx:
                            upd_bytes = _shape_bytes(shapes.get(names[idx], ""))
                    by += 2 * (upd_bytes or _shape_bytes(mm.group(1)) // 16)
                else:
                    by += 2 * _shape_bytes(mm.group(1))
            wm = re.search(
                r"while\(.*?\).*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", l
            )
            if wm:
                trips = _trip_from_line(l) or _trip_count(
                    comps_raw.get(wm.group(1), ("", []))[1], global_consts)
                calls[name].append((wm.group(2), trips))
                continue
            for cs in re.finditer(
                r"(?:to_apply|calls|body|branch_computations)=\{?%?([\w.\-]+)", l
            ):
                if cs.group(1) in comps_raw and cs.group(1) != name:
                    calls[name].append((cs.group(1), 1))
        own_flops[name] = fl
        own_bytes[name] = by

    total = {"flops": 0.0, "bytes": 0.0}

    def visit(comp: str, mult: int, depth=0):
        if depth > 60 or comp not in own_flops:
            return
        total["flops"] += own_flops[comp] * mult
        total["bytes"] += own_bytes[comp] * mult
        for callee, m in calls.get(comp, []):
            visit(callee, mult * m, depth + 1)

    visit(entry if entry else next(iter(comps_raw), ""), 1)
    return {"flops_weighted": total["flops"], "bytes_weighted": total["bytes"]}


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    entry = _find_entry(hlo_text)
    global_consts: Dict[str, int] = {}
    for lines in comps.values():
        global_consts.update(_constants(lines))

    # map: computation -> list of (kind, bytes)
    own: Dict[str, List[Tuple[str, int]]] = {}
    calls: Dict[str, List[Tuple[str, int]]] = {}  # (callee, multiplier)
    for name, lines in comps.items():
        own[name] = []
        calls[name] = []
        for l in lines:
            cm = _COLL_RE.search(l)
            if cm:
                own[name].append((cm.group(2), _shape_bytes(cm.group(1))))
            wm = re.search(
                r"while\(.*?\).*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", l
            )
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_from_line(l) or _trip_count(
                    comps.get(cond, []), global_consts)
                calls[name].append((body, trips))
                continue
            for cs in re.finditer(
                r"(?:to_apply|body|branch_computations)=\{?%?([\w.\-]+)", l
            ):
                callee = cs.group(1)
                if callee in comps and callee != name:
                    calls[name].append((callee, 1))
            fm = re.search(r"fusion\(.*?\).*?calls=%?([\w.\-]+)", l)
            if fm:
                calls[name].append((fm.group(1), 1))

    stats = CollectiveStats(defaultdict(int), defaultdict(int))
    seen: Dict[str, None] = {}

    def visit(comp: str, mult: int, depth=0):
        if depth > 50 or comp not in own:
            return
        for kind, b in own[comp]:
            stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b * mult
            stats.ops_by_kind[kind] = stats.ops_by_kind.get(kind, 0) + mult
        for callee, m in calls.get(comp, []):
            visit(callee, mult * m, depth + 1)

    if entry:
        visit(entry, 1)
    else:  # fallback: flat count
        for comp in comps:
            visit(comp, 1)
    stats.bytes_by_kind = dict(stats.bytes_by_kind)
    stats.ops_by_kind = dict(stats.ops_by_kind)
    return stats
