"""Production serving launcher: sharded prefill + batched decode on a mesh.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2-0.5b --smoke --mesh 4x2 --batch 8 --steps 16

Weights are TP-sharded over 'model' and (per the D2 finding in
EXPERIMENTS.md) replicated over 'data'; the KV cache shards batch over
'data' and heads/seq over 'model' per train/sharding.py rules.
"""

import os
import sys

if __name__ == "__main__" and "--devices" in sys.argv:
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ.setdefault("XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")

import argparse
import time

import jax

from repro.launch.mesh import set_global_mesh
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import hints
from repro.models.transformer import decode_step, init_cache, init_params
from repro.train.sharding import cache_pspecs, mesh_axes, named, param_pspecs


def build_mesh(spec: str) -> Mesh:
    dims = [int(x) for x in spec.split("x")]
    devs = jax.devices()
    need = int(np.prod(dims))
    assert len(devs) >= need
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return Mesh(np.array(devs[:need]).reshape(dims), names)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="2x2")
    ap.add_argument("--devices", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    mesh = build_mesh(args.mesh)
    dp_axes, model_axis = mesh_axes(mesh)
    set_global_mesh(mesh)
    hints.set_hint("hidden", P(dp_axes, None, None))
    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"mesh {dict(mesh.shape)}  model {cfg.name}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg, params, mesh, no_fsdp=True)  # serving: no ZeRO
    params = jax.device_put(params, named(mesh, pspecs))

    cache = init_cache(cfg, args.batch, args.max_seq)
    cspecs = cache_pspecs(cfg, mesh, cache)
    cache = jax.device_put(cache, named(mesh, cspecs))

    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t),
                   donate_argnums=(1,))
    tok = jnp.ones((args.batch, 1), jnp.int32)
    # warmup + timed decode
    logits, cache = step(params, cache, tok)
    jax.block_until_ready(logits)
    t0 = time.time()
    for i in range(args.steps):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    tput = args.batch * args.steps / dt
    print(f"{args.steps} decode steps, batch {args.batch}: "
          f"{dt/args.steps*1e3:.1f} ms/step, {tput:.1f} tok/s")
    assert bool(jnp.isfinite(logits).all())
    print("OK")


if __name__ == "__main__":
    main()
