"""Roofline-term derivation from the dry-run cell records.

TPU v5e hardware constants (per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI per link       ~50 GB/s

Per (arch x shape x mesh) cell, from the compiled artifact:
    compute term   = flops_weighted / PEAK            [s]
    memory term    = bytes_weighted / HBM_BW          [s]
    collective term= collective_bytes / ICI_BW        [s]
    latency term   = collective_rounds * ALPHA        [s]  (paper metric)

flops_weighted / bytes_weighted / collective_bytes are PER-DEVICE with
while-loop bodies multiplied by their trip counts (see hlo_analysis).
The bottleneck is the max term; the roofline fraction is
compute_term / max(all terms) -- how close the cell runs to the compute
roofline if the dominant term were the only cost.

MODEL_FLOPS = passes * N_active * tokens / devices; ratio to
flops_weighted shows how much compiled compute is useful (catches
remat / capacity-factor / padding waste).

    PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ALPHA = 1e-6       # per-round collective latency
HBM_BYTES = 16e9   # v5e HBM capacity

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"
)


def load_cells(mesh: str = "single", tag: str = "") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        d = json.load(open(path))
        if d.get("mesh") != mesh or d.get("tag", "") != (tag or ""):
            continue
        out.append(d)
    return out


def terms(d: Dict) -> Dict:
    if "skipped" in d:
        return {"arch": d["arch"], "shape": d["shape"], "skipped": d["skipped"]}
    ct = d["flops_weighted"] / PEAK_FLOPS
    mt = d["bytes_weighted"] / HBM_BW
    xt = d["collective_bytes"] / ICI_BW
    lt = d["collective_rounds"] * ALPHA
    dom = max(("compute", ct), ("memory", mt), ("collective", xt),
              ("latency", lt), key=lambda kv: kv[1])
    total = max(ct, mt, xt, lt)
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "compute_s": ct,
        "memory_s": mt,
        "collective_s": xt,
        "latency_s": lt,
        "bottleneck": dom[0],
        "roofline_frac": ct / total if total else 0.0,
        "model_flops": d["model_flops_per_device"],
        "useful_ratio": (
            d["model_flops_per_device"] / d["flops_weighted"]
            if d["flops_weighted"] else 0.0
        ),
        "fits_hbm": d["memory"]["peak_estimate_bytes"] <= HBM_BYTES,
        "peak_gb": d["memory"]["peak_estimate_bytes"] / 1e9,
        "microbatches": d.get("microbatches"),
        "tag": d.get("tag", ""),
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | latency | "
           "bottleneck | roofline frac | useful FLOPs | fits 16GB |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | -- | -- | -- | -- | "
                f"skipped (full attention) | -- | -- | -- |"
            )
            continue
        fits = "yes" if r["fits_hbm"] else f"NO ({r['peak_gb']:.0f}GB)"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{fmt_s(r['latency_s'])} | {r['bottleneck']} | "
            f"{r['roofline_frac']*100:.0f}% | {r['useful_ratio']*100:.0f}% | "
            f"{fits} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = [terms(d) for d in load_cells(args.mesh, args.tag)]
    if args.md:
        print(markdown_table(rows))
    else:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
