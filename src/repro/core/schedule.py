"""Round-optimal n-block broadcast schedules in O(log p) time.

Faithful implementation of:

    Jesper Larsson Träff, "Round-optimal n-Block Broadcast Schedules in
    Logarithmic Time", 2023 (arXiv:2312.11236).

The paper gives O(log p)-per-processor algorithms for computing the
receive and send schedules that drive a round-optimal (n-1+ceil(log2 p)
communication rounds) broadcast of n indivisible blocks on a
ceil(log2 p)-regular circulant graph over p processors, and the
corresponding all-to-all broadcast (irregular allgather).

Algorithm numbering follows the paper:

  * Algorithm 3 -> :func:`compute_skips`
  * Algorithm 4 -> :func:`baseblock`
  * Algorithm 5 -> ``_dfs_blocks`` (inner backtracking search)
  * Algorithm 6 -> :func:`recv_schedule`
  * Algorithm 7/8/9 -> :func:`send_schedule`

All functions are pure Python on ints; they are host-side trace-time
computations (a schedule is O(log p) ints), never traced by JAX.
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import List, Sequence, Tuple

__all__ = [
    "ceil_log2",
    "compute_skips",
    "baseblock",
    "recv_schedule",
    "send_schedule",
    "schedule_tables",
    "num_rounds",
    "virtual_rounds",
]


def ceil_log2(p: int) -> int:
    """q = ceil(log2 p) for p >= 1."""
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    return (p - 1).bit_length()


@lru_cache(maxsize=None)
def compute_skips(p: int) -> Tuple[int, ...]:
    """Algorithm 3: skips (jumps) of the p-processor circulant graph.

    Returns a tuple of length q+1 with skip[q] = p and
    skip[k] = ceil(skip[k+1] / 2) for k = q-1 .. 0.  For all p >= 2 this
    ends with skip[0] = 1 and skip[1] = 2 (Observation 2 ff.).
    """
    q = ceil_log2(p)
    skip = [0] * (q + 1)
    skip[q] = p
    for k in range(q - 1, -1, -1):
        skip[k] = skip[k + 1] - skip[k + 1] // 2  # = ceil(skip[k+1]/2)
    return tuple(skip)


def baseblock(r: int, skip: Sequence[int], q: int) -> int:
    """Algorithm 4: smallest skip index of the canonical skip sequence of r.

    The canonical skip sequence is the greedy largest-skip-first
    decomposition of r into a sum of distinct skips (Lemma 1).  The
    baseblock is the first (smallest) index in that sequence; by
    convention the root r=0 has baseblock q (empty sequence).
    """
    k = q
    while k > 0:
        k -= 1
        if skip[k] == r:
            return k
        if skip[k] < r:
            r -= skip[k]
    return q


def _dfs_blocks(
    r: int,
    rp: int,
    s_cell: List[int],
    e: int,
    k: int,
    recvblock: List[int],
    skip: Sequence[int],
    nxt: List[int],
    prv: List[int],
    q: int,
    stats: List[int] | None = None,
) -> int:
    """Algorithm 5: greedy backtracking DFS with removal of accepted blocks.

    ``r`` is the (virtual) target processor p + rank, ``rp`` the current
    path sum r', ``s_cell`` a one-element list holding the shared state s
    (sum of the skips on the most recently accepted path), ``e`` the skip
    index to start scanning from, ``k`` the next round to fill.

    ``nxt``/``prv`` implement the doubly linked list of remaining skip
    indices in decreasing order; index q+1 slots are offset by +1 so the
    sentinel -1 maps to slot 0 (we simply index with e+1).

    Returns the updated k.  ``stats`` (if given) counts recursive calls,
    for validating Proposition 1 (at most 2q calls).
    """
    # Entry guard r' <= r - skip[k+1]; for k >= q treat skip[q+1] as +inf
    # (the guard then fails and the call is a no-op).
    if k + 1 > q or rp > r - skip[k + 1]:
        return k
    while e != -1:
        if k <= q and rp + skip[e] <= r - skip[k]:  # e admissible for k
            if stats is not None:
                stats[0] += 1
            k = _dfs_blocks(
                r, rp + skip[e], s_cell, e, k, recvblock, skip, nxt, prv, q, stats
            )
            # Even if k changed, admissibility still holds; accept e if the
            # path is canonical (dedup against most recently accepted sum s).
            if (k + 1 <= q and rp <= r - skip[k + 1]) and s_cell[0] > rp + skip[e]:
                s_cell[0] = rp + skip[e]
                recvblock[k] = e
                k += 1
                # remove e by unlinking (slot layout: index x lives at slot x+1)
                pe, ne = prv[e + 1], nxt[e + 1]
                nxt[pe + 1] = ne
                prv[ne + 1] = pe
        e = nxt[e + 1]  # values stored are actual indices (-1 = sentinel)
    return k


def recv_schedule(
    p: int,
    r: int,
    skip: Sequence[int] | None = None,
    stats: List[int] | None = None,
) -> List[int]:
    """Algorithm 6: receive schedule for processor r among p.

    Returns recvblock[0..q-1] with exactly one non-negative entry, the
    baseblock b of r (for the root r=0 all entries are negative), and the
    other entries forming {-1,...,-q} \\ {b-q} (Correctness Condition 3).
    Runs in O(log p) operations (Proposition 1).
    """
    q = ceil_log2(p)
    if skip is None:
        skip = compute_skips(p)
    if q == 0:
        return []
    # Doubly linked list over skip indices q..0, decreasing, with sentinel -1.
    # Slot layout: index e lives at slot e+1; sentinel -1 at slot 0.
    nxt = [0] * (q + 2)
    prv = [0] * (q + 2)
    for e in range(q + 1):
        nxt[e + 1] = e - 1
        prv[e + 1] = e + 1
    prv[q + 1] = -1
    nxt[0] = q  # next[-1] = q (head of the decreasing list)
    prv[0] = 0  # prev[-1] = 0 (tail)

    b = baseblock(r, skip, q)
    # Remove baseblock index b by unlinking.
    nxt[prv[b + 1] + 1] = nxt[b + 1]
    prv[nxt[b + 1] + 1] = prv[b + 1]

    recvblock = [0] * q
    s_cell = [p + p]
    _dfs_blocks(p + r, 0, s_cell, q, 0, recvblock, skip, nxt, prv, q, stats)

    for k in range(q):
        if recvblock[k] == q:
            recvblock[k] = b
        else:
            recvblock[k] -= q
    return recvblock


def send_schedule(
    p: int,
    r: int,
    skip: Sequence[int] | None = None,
    violations: List[int] | None = None,
) -> List[int]:
    """Algorithms 7/8/9: send schedule for processor r among p in O(log p).

    Satisfies sendblock[k]_r == recvblock[k]_{(r+skip[k]) mod p} for all
    rounds k (Proposition 4).  At most a constant number (<= 4) of
    "violations" fall back to a recv-schedule computation for the
    to-processor (Proposition 3); ``violations`` (if given) counts them.
    """
    q = ceil_log2(p)
    if skip is None:
        skip = compute_skips(p)
    if q == 0:
        return []
    sendblock = [0] * q
    if r == 0:
        for k in range(q):
            sendblock[k] = k
        return sendblock

    def _violation(k: int) -> int:
        if violations is not None:
            violations[0] += 1
        return recv_schedule(p, (r + skip[k]) % p, skip)[k]

    b = baseblock(r, skip, q)
    rp, c, e = r, b, p
    for k in range(q - 1, 0, -1):
        if rp < skip[k]:
            # ---- lower part (Algorithm 8) ----
            # NOTE: strict "<" as in the paper's pseudocode (the prose says
            # "<="; exhaustive verification shows strict is the correct one,
            # e.g. p=33, r=31, k=2 needs the Violation-(1) fallback).
            if e < skip[k - 1] or (k == 1 and b > 0):
                sendblock[k] = c
            elif rp == 0 and k == 2:
                if e == 2 and skip[2] == 3:
                    sendblock[k] = _violation(k)  # Violation (1)
                else:
                    sendblock[k] = c
            elif rp == 0 and skip[k] == 5:  # implies k == 3
                if e == 3:
                    sendblock[k] = _violation(k)  # Violation (1)
                else:
                    sendblock[k] = c
            elif rp + skip[k] >= e:
                sendblock[k] = _violation(k)  # Violation (2)
            else:
                sendblock[k] = c
            if e > skip[k]:
                e = skip[k]
        else:
            # ---- upper part (Algorithm 9) ----
            c = k - q
            if k == 1 or rp > skip[k] or e - skip[k] < skip[k - 1]:
                sendblock[k] = c
            elif k == 2:
                if skip[2] == 3 and e == 5:
                    sendblock[k] = _violation(k)  # Violation (1)
                else:
                    sendblock[k] = c
            elif skip[k] == 5:  # implies k == 3
                if e == 8:
                    sendblock[k] = _violation(k)  # Violation (1)
                else:
                    sendblock[k] = c
            elif rp + skip[k] > e:
                sendblock[k] = _violation(k)  # Violation (3)
            else:
                sendblock[k] = c
            rp, e = rp - skip[k], e - skip[k]
    sendblock[0] = b - q
    return sendblock


def schedule_tables(p: int):
    """All-ranks schedule tables as lists of lists: (recv[p][q], send[p][q]).

    Convenience for building the JAX collective constants; per-rank cost
    stays O(log p), total O(p log p).
    """
    skip = compute_skips(p)
    recv = [recv_schedule(p, r, skip) for r in range(p)]
    send = [send_schedule(p, r, skip) for r in range(p)]
    return recv, send


def num_rounds(p: int, n: int) -> int:
    """Optimal number of communication rounds: n - 1 + ceil(log2 p).

    For p == 1 no communication happens at all, so 0.
    """
    if p == 1:
        return 0
    return n - 1 + ceil_log2(p)


def virtual_rounds(p: int, n: int) -> int:
    """x: number of initial virtual rounds so that n-1+q+x is a multiple of q."""
    q = ceil_log2(p)
    if q == 0:
        return 0
    return (q - (n - 1 + q) % q) % q
