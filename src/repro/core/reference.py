"""Legacy-cost schedule constructions used as baselines (paper [12,13,16]).

The paper improves schedule computation from O(p log^2 p) [16] and
O(log^3 p) [12,13] per processor down to O(log p).  The original legacy
code is not published in algorithmic form (the paper notes its send-side
improvements "were not documented in [12,13]"), so for the Table-3 style
benchmark we provide *cost-faithful* stand-ins that produce exactly the
same schedules as the new algorithms but with the legacy asymptotic
costs:

  * ``recv_schedule_legacy`` -- O(log^2 p) per processor: recomputes the
    whole DFS prefix for every round k (q restarts of an O(q) search),
    which is precisely the restart structure that the new algorithm's
    shared backtracking state eliminates.
  * ``send_schedule_legacy`` -- O(log^3 p) per processor: the
    "straightforward computation" of §2.4, sendblock[k]_r =
    recvblock[k]_{(r+skip[k]) mod p}, i.e. q legacy receive-schedule
    computations.
  * ``send_schedule_from_recv`` -- the same construction on top of the
    new O(log p) receive schedule: O(log^2 p), matching what the paper
    reports the old implementation actually achieved in practice.

Differential tests assert all of these agree with the O(log p)
algorithms for every processor.
"""

from __future__ import annotations

from typing import List, Sequence

from .schedule import ceil_log2, compute_skips, recv_schedule

__all__ = [
    "recv_schedule_legacy",
    "send_schedule_legacy",
    "send_schedule_from_recv",
]


def recv_schedule_legacy(p: int, r: int, skip: Sequence[int] | None = None) -> List[int]:
    """O(log^2 p) receive schedule via q restarts of the round search.

    For each round k the search is restarted from scratch and run until
    entry k is produced; only that entry is kept.  Identical output to
    :func:`repro.core.schedule.recv_schedule`, with the legacy quadratic
    per-processor cost.
    """
    q = ceil_log2(p)
    if skip is None:
        skip = compute_skips(p)
    if q == 0:
        return []
    out = [0] * q
    for k in range(q):
        # Restart: recompute rounds 0..k and keep round k only.
        full = recv_schedule(p, r, skip)
        out[k] = full[k]
        # (A faithful restart recomputes the prefix; recomputing the whole
        # schedule has the same Theta(q) cost per restart.)
    return out


def send_schedule_from_recv(
    p: int,
    r: int,
    skip: Sequence[int] | None = None,
    recv_fn=recv_schedule,
) -> List[int]:
    """sendblock[k]_r = recvblock[k]_{(r+skip[k]) mod p}.

    The straightforward O(q x recv-cost) send construction that §2.4
    replaces: O(log^2 p) with the new receive algorithm, O(log^3 p) with
    the legacy one.
    """
    q = ceil_log2(p)
    if skip is None:
        skip = compute_skips(p)
    return [recv_fn(p, (r + skip[k]) % p, skip)[k] for k in range(q)]


def send_schedule_legacy(p: int, r: int, skip: Sequence[int] | None = None) -> List[int]:
    """O(log^3 p) send schedule: q legacy receive-schedule computations."""
    return send_schedule_from_recv(p, r, skip, recv_fn=recv_schedule_legacy)
