"""Version-compat shims for jax APIs that moved between releases.

The container floor is jax 0.4.x; new call sites should import from
here rather than sniffing ``jax``/``jax.experimental`` themselves.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map across jax versions (moved out of jax.experimental in
    0.6; the old entry point spells ``check_vma`` as ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as legacy_shard_map

    return legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
