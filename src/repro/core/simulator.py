"""Round-based message-passing simulator for Algorithm 1 and Algorithm 2.

Executes the paper's broadcast / all-to-all broadcast algorithms over a
simulated fully-connected, one-ported, bidirectional network and checks
that after exactly n-1+q rounds every processor holds every block.  This
is the end-to-end functional oracle for the schedule constructions (and
doubles as a latency/volume counter for the benchmark cost models).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .engine import get_bundle
from .schedule import num_rounds

__all__ = ["simulate_broadcast", "simulate_allgather", "SimResult"]


@dataclass
class SimResult:
    rounds: int                      # actual communication rounds executed
    optimal_rounds: int              # n - 1 + ceil(log2 p)
    messages: int = 0                # point-to-point messages sent
    blocks_moved: int = 0            # total blocks transferred
    buffers: Optional[list] = None   # final per-processor buffers


def simulate_broadcast(p: int, n: int, root: int = 0, keep_buffers: bool = False) -> SimResult:
    """Algorithm 1: broadcast n blocks from ``root`` to all p processors.

    Simulates all rounds; asserts the final state is complete.  Block
    payloads are (block_index,) tuples so content errors are caught, not
    just counts.  The rooted engine bundle indexes schedules by real
    rank (rank renumbering of paper §2.1 folded in by the engine).
    """
    # buffer[r][j] holds the payload of block j at processor r (or None).
    buf: List[List[Optional[int]]] = [[None] * n for _ in range(p)]
    for j in range(n):
        buf[root][j] = j

    res = SimResult(rounds=0, optimal_rounds=num_rounds(p, n))
    if p == 1:
        res.buffers = buf if keep_buffers else None
        return res

    bundle = get_bundle(p, root)
    q, skip = bundle.q, bundle.skips
    x = bundle.virtual_rounds(n)
    # Working copies of the per-round block indices (x virtual rounds
    # folded in); incremented by q after each use exactly as in
    # Algorithm 1.  Rows are indexed by REAL rank.
    recv_adj, send_adj = bundle.adjusted_tables(n)
    rb = recv_adj.tolist()
    sb = send_adj.tolist()

    for i in range(x, n + q - 1 + x):
        k = i % q
        # Gather the messages of this round first (synchronous round model):
        # rank r sends buf[r][sb[r][k]] to (r + skip[k]) % p.
        msgs: List[Tuple[int, int, Optional[int]]] = []  # (dst, blk, payload)
        for r in range(p):
            blk = sb[r][k]
            t = (r + skip[k]) % p
            if blk < 0 or t == root:
                continue  # nonexistent block / never send to the root
            blk_eff = min(blk, n - 1)
            payload = buf[r][blk_eff]
            assert payload is not None, (
                f"p={p} n={n} round={i} k={k}: rank {r} must send block "
                f"{blk_eff} it does not have"
            )
            msgs.append((t, blk_eff, payload))
        for dst, blk, payload in msgs:
            rblk = rb[dst][k]
            assert rblk >= 0, f"receiver {dst} got unexpected block in round {i}"
            rblk_eff = min(rblk, n - 1)
            assert rblk_eff == blk, (
                f"p={p} n={n} round={i}: rank {dst} expected block {rblk_eff}, "
                f"got {blk}"
            )
            assert payload == blk, "payload corrupted"
            buf[dst][blk] = payload
            res.messages += 1
            res.blocks_moved += 1
        for r in range(p):
            sb[r][k] += q
            rb[r][k] += q
        res.rounds += 1

    for r in range(p):
        for j in range(n):
            assert buf[r][j] == j, f"p={p} n={n}: rank {r} missing block {j}"
    assert res.rounds == res.optimal_rounds
    res.buffers = buf if keep_buffers else None
    return res


def simulate_allgather(
    p: int,
    n: int,
    sizes: Optional[List[int]] = None,
    keep_buffers: bool = False,
) -> SimResult:
    """Algorithm 2: all-to-all broadcast (irregular allgather).

    Every processor j contributes n blocks (of per-processor size
    sizes[j] if given; sizes only affect the volume counter).  Verifies
    that after n-1+q rounds every processor holds all p*n blocks.
    """
    bundle = get_bundle(p)
    q, skip = bundle.q, bundle.skips
    x = bundle.virtual_rounds(n)
    recv = bundle.adjusted_tables(n)[0].tolist()

    # recvblocks[r][j][k]: schedule of rank r for root j = recv of (r-j) mod p
    # sendblocks[r][j][k] = recvblocks[f^k][j][k] with f^k = (r - skip[k]) % p
    # (both are realized by row rotation of the single recv table).

    buf: List[List[List[Optional[Tuple[int, int]]]]] = [
        [[None] * n for _ in range(p)] for _ in range(p)
    ]
    for j in range(p):
        for blk in range(n):
            buf[j][j][blk] = (j, blk)

    res = SimResult(rounds=0, optimal_rounds=num_rounds(p, n))
    if p == 1:
        res.buffers = buf if keep_buffers else None
        return res
    if sizes is None:
        sizes = [1] * p

    # Working per-(rank, root) block counters.
    rb = [[list(recv[(r - j) % p]) for j in range(p)] for r in range(p)]

    for i in range(x, n + q - 1 + x):
        k = i % q
        # Pack phase: every rank sends, for every root j != t, one block.
        round_msgs = []
        for r in range(p):
            t = (r + skip[k]) % p
            payloads: Dict[int, Tuple[int, Optional[Tuple[int, int]]]] = {}
            for j in range(p):
                if j == t:
                    continue  # t is root for j == t: already has it
                # sendblocks_r[j][k] = recvblocks[(j - skip[k]) mod p][k]
                #                    = recv_schedule((r - j + skip[k]) mod p)[k]
                # i.e. exactly what the to-processor t expects for root j.
                blk = rb[t][j][k]  # == sendblocks[r][j][k] (lockstep counters)
                if blk < 0:
                    continue
                blk_eff = min(blk, n - 1)
                payload = buf[r][j][blk_eff]
                assert payload is not None, (
                    f"p={p} n={n} round={i}: rank {r} missing block "
                    f"({j},{blk_eff}) to send"
                )
                payloads[j] = (blk_eff, payload)
                res.blocks_moved += 1
            round_msgs.append((r, t, payloads))
            res.messages += 1
        # Unpack phase.
        for r, t, payloads in round_msgs:
            for j, (blk, payload) in payloads.items():
                if j == t:
                    continue
                rblk = rb[t][j][k]
                rblk_eff = min(rblk, n - 1)
                assert rblk >= 0 and rblk_eff == blk, (
                    f"p={p} n={n} round={i}: root {j} rank {t} expected "
                    f"{rblk}, got {blk}"
                )
                assert payload == (j, blk)
                buf[t][j][blk] = payload
        for r in range(p):
            for j in range(p):
                rb[r][j][k] += q
        res.rounds += 1

    for r in range(p):
        for j in range(p):
            for blk in range(n):
                assert buf[r][j][blk] == (j, blk), (
                    f"p={p} n={n}: rank {r} missing block ({j},{blk})"
                )
    assert res.rounds == res.optimal_rounds
    res.buffers = buf if keep_buffers else None
    return res
