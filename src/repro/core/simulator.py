"""Round-based message-passing simulator for the whole collective family.

Executes the paper's broadcast / all-to-all broadcast algorithms -- and,
via the time-reversed schedules (Träff, arXiv:2407.18004), the derived
reduction / all-reduction -- over a simulated fully-connected,
one-ported, bidirectional network and checks that each collective
completes in exactly its optimal round count (n-1+q for broadcast /
all-broadcast / reduction, 2(n-1)+2q for the composed all-reduction).
This is the end-to-end functional oracle for the schedule constructions
(and doubles as a latency/volume counter for the benchmark cost models).

Backend certification: passing ``backend="jnp"`` or ``backend="pallas"``
additionally executes the collective's *data plane* -- the actual
round-step implementation of :mod:`repro.core.roundstep`, with the p
simulated ranks batched onto the kernel rows and the network exchange
realized as a row rotation -- and asserts that its final buffers match
the message-passing reference **bit-exactly**.  This is how the Pallas
(interpret-mode) kernels are certified against the NumPy reference on
CPU CI without any devices.  Certification is routed through the cached
host data plans of :mod:`repro.core.comm` (:func:`~repro.core.comm.
host_plan`), so sweeping a (p, n, root, op, backend) grid resolves slot
tables and step handles once per combination.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import get_bundle
from .schedule import num_rounds

__all__ = [
    "simulate_broadcast",
    "simulate_allgather",
    "simulate_allbroadcast",
    "simulate_reduce",
    "simulate_allreduce",
    "simulate_hier_broadcast",
    "simulate_hier_reduce",
    "simulate_hier_allreduce",
    "SimResult",
    "HierSimResult",
]

# Reduction operators: name -> (binary combine on numpy values).  Both are
# associative and commutative; the reversal delivers every contribution
# exactly once, so '+' is bit-exact and 'max' trivially so.
_OPS = {
    "+": np.add,
    "sum": np.add,
    "max": np.maximum,
}


@dataclass
class SimResult:
    rounds: int                      # actual communication rounds executed
    optimal_rounds: int              # n - 1 + ceil(log2 p)
    messages: int = 0                # point-to-point messages sent
    blocks_moved: int = 0            # total blocks transferred
    buffers: Optional[list] = None   # final per-processor buffers
    backend: Optional[str] = None    # data-plane backend certified (or None)


def simulate_broadcast(
    p: int,
    n: int,
    root: int = 0,
    keep_buffers: bool = False,
    payloads: Optional[List] = None,
    backend: Optional[str] = None,
) -> SimResult:
    """Algorithm 1: broadcast n blocks from ``root`` to all p processors.

    Simulates all rounds; asserts the final state is complete.  Block
    payloads default to the block index (so content errors are caught,
    not just counts); ``payloads`` substitutes real per-block values
    (e.g. the all-reduction return path), delivered and checked
    verbatim.  The rooted engine bundle indexes schedules by real rank
    (rank renumbering of paper §2.1 folded in by the engine).

    ``backend`` ("jnp" / "pallas") additionally executes the round-step
    data plane on the numeric payloads and asserts bit-exact agreement
    with this reference on every rank (see module docstring).
    """
    pay = list(payloads) if payloads is not None else list(range(n))
    assert len(pay) == n
    # buffer[r][j] holds the payload of block j at processor r (or None).
    buf: List[List[Optional[int]]] = [[None] * n for _ in range(p)]
    for j in range(n):
        buf[root][j] = pay[j]

    res = SimResult(rounds=0, optimal_rounds=num_rounds(p, n), backend=backend)
    if p == 1:
        res.buffers = buf if keep_buffers else None
        return res

    bundle = get_bundle(p, root)
    q, skip = bundle.q, bundle.skips
    x = bundle.virtual_rounds(n)
    # Working copies of the per-round block indices (x virtual rounds
    # folded in); incremented by q after each use exactly as in
    # Algorithm 1.  Rows are indexed by REAL rank.
    recv_adj, send_adj = bundle.adjusted_tables(n)
    rb = recv_adj.tolist()
    sb = send_adj.tolist()

    for i in range(x, n + q - 1 + x):
        k = i % q
        # Gather the messages of this round first (synchronous round model):
        # rank r sends buf[r][sb[r][k]] to (r + skip[k]) % p.
        msgs: List[Tuple[int, int, Optional[int]]] = []  # (dst, blk, payload)
        for r in range(p):
            blk = sb[r][k]
            t = (r + skip[k]) % p
            if blk < 0 or t == root:
                continue  # nonexistent block / never send to the root
            blk_eff = min(blk, n - 1)
            payload = buf[r][blk_eff]
            assert payload is not None, (
                f"p={p} n={n} round={i} k={k}: rank {r} must send block "
                f"{blk_eff} it does not have"
            )
            msgs.append((t, blk_eff, payload))
        for dst, blk, payload in msgs:
            rblk = rb[dst][k]
            assert rblk >= 0, f"receiver {dst} got unexpected block in round {i}"
            rblk_eff = min(rblk, n - 1)
            assert rblk_eff == blk, (
                f"p={p} n={n} round={i}: rank {dst} expected block {rblk_eff}, "
                f"got {blk}"
            )
            assert np.array_equal(payload, pay[blk]), "payload corrupted"
            buf[dst][blk] = payload
            res.messages += 1
            res.blocks_moved += 1
        for r in range(p):
            sb[r][k] += q
            rb[r][k] += q
        res.rounds += 1

    for r in range(p):
        for j in range(n):
            assert buf[r][j] is not None and np.array_equal(buf[r][j], pay[j]), (
                f"p={p} n={n}: rank {r} missing block {j}"
            )
    assert res.rounds == res.optimal_rounds
    if backend is not None:
        from .comm import host_plan

        vals = np.asarray(pay)
        got = host_plan("broadcast", p, n, root=root, backend=backend).run(vals)
        expect = got[root]  # reference payloads in data-plane block shape
        assert np.array_equal(expect.reshape(vals.shape), vals)
        for r in range(p):
            assert np.array_equal(got[r], expect), (
                f"p={p} n={n} root={root}: {backend} data plane diverged "
                f"from the reference at rank {r}"
            )
    res.buffers = buf if keep_buffers else None
    return res


def simulate_allgather(
    p: int,
    n: int,
    sizes: Optional[List[int]] = None,
    keep_buffers: bool = False,
    backend: Optional[str] = None,
) -> SimResult:
    """Algorithm 2: all-to-all broadcast (irregular allgather).

    Every processor j contributes n blocks (of per-processor size
    sizes[j] if given; sizes only affect the volume counter).  Verifies
    that after n-1+q rounds every processor holds all p*n blocks.
    ``backend`` additionally certifies the round-step data plane
    bit-exactly, as in :func:`simulate_broadcast`.
    """
    bundle = get_bundle(p)
    q, skip = bundle.q, bundle.skips
    x = bundle.virtual_rounds(n)
    recv = bundle.adjusted_tables(n)[0].tolist()

    # recvblocks[r][j][k]: schedule of rank r for root j = recv of (r-j) mod p
    # sendblocks[r][j][k] = recvblocks[f^k][j][k] with f^k = (r - skip[k]) % p
    # (both are realized by row rotation of the single recv table).

    buf: List[List[List[Optional[Tuple[int, int]]]]] = [
        [[None] * n for _ in range(p)] for _ in range(p)
    ]
    for j in range(p):
        for blk in range(n):
            buf[j][j][blk] = (j, blk)

    res = SimResult(rounds=0, optimal_rounds=num_rounds(p, n), backend=backend)
    if p == 1:
        res.buffers = buf if keep_buffers else None
        return res
    if sizes is None:
        sizes = [1] * p

    # Working per-(rank, root) block counters.
    rb = [[list(recv[(r - j) % p]) for j in range(p)] for r in range(p)]

    for i in range(x, n + q - 1 + x):
        k = i % q
        # Pack phase: every rank sends, for every root j != t, one block.
        round_msgs = []
        for r in range(p):
            t = (r + skip[k]) % p
            payloads: Dict[int, Tuple[int, Optional[Tuple[int, int]]]] = {}
            for j in range(p):
                if j == t:
                    continue  # t is root for j == t: already has it
                # sendblocks_r[j][k] = recvblocks[(j - skip[k]) mod p][k]
                #                    = recv_schedule((r - j + skip[k]) mod p)[k]
                # i.e. exactly what the to-processor t expects for root j.
                blk = rb[t][j][k]  # == sendblocks[r][j][k] (lockstep counters)
                if blk < 0:
                    continue
                blk_eff = min(blk, n - 1)
                payload = buf[r][j][blk_eff]
                assert payload is not None, (
                    f"p={p} n={n} round={i}: rank {r} missing block "
                    f"({j},{blk_eff}) to send"
                )
                payloads[j] = (blk_eff, payload)
                res.blocks_moved += 1
            round_msgs.append((r, t, payloads))
            res.messages += 1
        # Unpack phase.
        for r, t, payloads in round_msgs:
            for j, (blk, payload) in payloads.items():
                if j == t:
                    continue
                rblk = rb[t][j][k]
                rblk_eff = min(rblk, n - 1)
                assert rblk >= 0 and rblk_eff == blk, (
                    f"p={p} n={n} round={i}: root {j} rank {t} expected "
                    f"{rblk}, got {blk}"
                )
                assert payload == (j, blk)
                buf[t][j][blk] = payload
        for r in range(p):
            for j in range(p):
                rb[r][j][k] += q
        res.rounds += 1

    for r in range(p):
        for j in range(p):
            for blk in range(n):
                assert buf[r][j][blk] == (j, blk), (
                    f"p={p} n={n}: rank {r} missing block ({j},{blk})"
                )
    assert res.rounds == res.optimal_rounds
    if backend is not None:
        from .comm import host_plan

        # Distinct (root, block) payload values, delivered everywhere.
        vals = np.arange(p * n, dtype=np.int64).reshape(p, n) * 7 + 3
        got = host_plan("allgather", p, n, backend=backend).run(vals)
        for r in range(p):
            assert np.array_equal(got[r].reshape(p, n), vals), (
                f"p={p} n={n}: {backend} data plane diverged from the "
                f"reference at rank {r}"
            )
    res.buffers = buf if keep_buffers else None
    return res


def simulate_allbroadcast(
    p: int,
    n: int,
    sizes: Optional[List[int]] = None,
    keep_buffers: bool = False,
    backend: Optional[str] = None,
) -> SimResult:
    """All-broadcast (the paper's name for all-to-all broadcast).

    Every processor broadcasts its n blocks to every other processor in
    the same n-1+q rounds; identical to :func:`simulate_allgather`, kept
    under the collective-family name of arXiv:2407.18004.
    """
    return simulate_allgather(
        p, n, sizes=sizes, keep_buffers=keep_buffers, backend=backend
    )


# --------------------------------------------------- reversed schedules


def simulate_reduce(
    p: int,
    n: int,
    root: int = 0,
    op: str = "+",
    values: Optional[np.ndarray] = None,
    keep_buffers: bool = True,
    backend: Optional[str] = None,
) -> SimResult:
    """Reduction of n blocks to ``root`` by time-reversing Algorithm 1.

    Every processor contributes ``values[r]`` (shape [p, n]; a seeded
    random int array when omitted).  Reduction round t replays forward
    round R-1-t with edges flipped: rank r forwards the partial of the
    block it forward-*received* in that round to its forward
    from-neighbor (r - skip[k]) % p, drains it, and accumulates the
    incoming partial into the block it forward-*sent*.  After exactly
    R = n-1+q rounds the root holds the op-reduction of every block and
    every other rank is fully drained -- both asserted, along with
    exactly-once accumulation of every (origin rank, block) contribution.

    ``res.buffers[r][j]`` is rank r's final partial of block j (the
    op-identity is represented as None; ``buffers[root]`` is the result).
    ``backend`` ("jnp" / "pallas") additionally executes the reversed
    round-step data plane -- the fused accumulate+capture/drain kernel
    over all p ranks at once -- and asserts the root's result matches
    this reference bit-exactly (for float ``+`` too: both accumulate in
    the same schedule order).
    """
    opf = _OPS[op]
    if values is None:
        values = np.arange(p * n, dtype=np.int64).reshape(p, n) ** 2 % 1013
    values = np.asarray(values)
    assert values.shape[0] == p and values.shape[1] == n

    # Partial state: vals[r][j] (None == op identity / drained) and the
    # multiset-of-origins certificate contrib[r][j].
    vals: List[List[Optional[np.ndarray]]] = [
        [values[r][j] for j in range(n)] for r in range(p)
    ]
    contrib: List[List[set]] = [[{r} for _ in range(n)] for r in range(p)]

    res = SimResult(rounds=0, optimal_rounds=num_rounds(p, n), backend=backend)
    if p == 1:
        res.buffers = vals if keep_buffers else None
        return res

    bundle = get_bundle(p, root)
    skip = bundle.skips
    fwd_blocks, acc_blocks, ks = bundle.reversed_per_round_tables(n)

    for t in range(fwd_blocks.shape[0]):
        k = int(ks[t])
        # Pack phase: capture every forwarded partial before any drain
        # (synchronous round model; a rank may forward and accumulate the
        # same clamped block in one round -- capture-drain-accumulate).
        msgs: List[Tuple[int, int, int, Optional[np.ndarray], set]] = []
        for r in range(p):
            e = int(fwd_blocks[t, r])
            # Idle entry, or the root: forward rounds never send TO the
            # root (it has everything), so the reversal never sends FROM
            # it (phase offsets can lift its negative entries >= 0 in
            # final-phase capped rounds -- those forward edges were the
            # suppressed redundant re-sends to the root).
            if e < 0 or r == root:
                continue
            blk = min(e, n - 1)
            dst = (r - skip[k]) % p
            msgs.append((r, dst, blk, vals[r][blk], contrib[r][blk]))
            res.messages += 1
            res.blocks_moved += 1
        # Drain phase: a forwarded partial leaves its sender.
        for r, _, blk, _, _ in msgs:
            vals[r][blk] = None
            contrib[r][blk] = set()
        # Accumulate phase.
        for r, dst, blk, v, c in msgs:
            e = int(acc_blocks[t, dst])
            assert e >= 0 and min(e, n - 1) == blk, (
                f"p={p} n={n} round={t}: rank {dst} expected block "
                f"{e}, got {blk} from {r}"
            )
            if not c:
                continue  # an already-drained (identity) partial
            assert contrib[dst][blk].isdisjoint(c), (
                f"p={p} n={n} round={t}: duplicate contribution "
                f"{contrib[dst][blk] & c} for block {blk} at rank {dst}"
            )
            contrib[dst][blk] |= c
            vals[dst][blk] = v if vals[dst][blk] is None else opf(vals[dst][blk], v)
        res.rounds += 1

    everyone = set(range(p))
    for j in range(n):
        assert contrib[root][j] == everyone, (
            f"p={p} n={n}: root {root} missing contributions "
            f"{everyone - contrib[root][j]} for block {j}"
        )
    for r in range(p):
        if r == root:
            continue
        for j in range(n):
            assert not contrib[r][j], (
                f"p={p} n={n}: rank {r} kept a partial of block {j}"
            )
    assert res.rounds == res.optimal_rounds
    if backend is not None:
        from .comm import host_plan

        got = host_plan("reduce", p, n, root=root, op=op,
                        backend=backend).run(values)
        ref_root = np.stack([np.asarray(vals[root][j]) for j in range(n)])
        assert np.array_equal(got[root].reshape(ref_root.shape), ref_root), (
            f"p={p} n={n} root={root} op={op}: {backend} data plane "
            f"diverged from the reference reduction"
        )
    res.buffers = vals if keep_buffers else None
    return res


def simulate_allreduce(
    p: int,
    n: int,
    root: int = 0,
    op: str = "+",
    values: Optional[np.ndarray] = None,
    keep_buffers: bool = True,
    backend: Optional[str] = None,
) -> SimResult:
    """All-reduction: reduce to ``root`` then broadcast the result back.

    The reversed reduction (n-1+q rounds) composes with the forward
    broadcast (n-1+q rounds) on the same cached bundle, for a total of
    exactly 2(n-1) + 2*ceil(log2 p) rounds.  The return path runs the
    payload-checked Algorithm-1 simulation carrying the reduced blocks,
    so every rank provably ends with the op-reduction of every block.
    ``backend`` certifies the round-step data plane of *both* phases
    bit-exactly against the reference, as in :func:`simulate_reduce` /
    :func:`simulate_broadcast`.
    """
    red = simulate_reduce(
        p, n, root=root, op=op, values=values, keep_buffers=True,
        backend=backend,
    )
    res = SimResult(
        rounds=red.rounds,
        optimal_rounds=2 * num_rounds(p, n),
        messages=red.messages,
        blocks_moved=red.blocks_moved,
        backend=backend,
    )
    reduced = red.buffers[root]
    bcast = simulate_broadcast(
        p, n, root=root, keep_buffers=keep_buffers, payloads=reduced,
        backend=backend,
    )
    res.rounds += bcast.rounds
    res.messages += bcast.messages
    res.blocks_moved += bcast.blocks_moved
    assert res.rounds == res.optimal_rounds
    res.buffers = bcast.buffers
    return res


# ---------------------------------------------- hierarchical composition
#
# Two-level (nodes x cores) collectives: one flat circulant phase per
# level (repro.core.hier).  The inter phase runs among the node leaders
# and the intra phases run inside every node *in parallel*, so the
# composed round count is the SUM of the per-level optima while the
# per-node simulations each re-certify their own level (payload
# delivery / exactly-once contribution certificates come from the flat
# simulators, which raise on any violation).  ``backend`` additionally
# executes the composed hierarchical data plane
# (:func:`repro.core.hier.hier_host_plan`) and asserts it bit-exact
# against the NumPy reference -- this is how the 36x32 evaluation
# topology is certified on CPU CI for both round-step backends.


@dataclass
class HierSimResult:
    rounds: int                      # composed communication rounds
    optimal_rounds: int              # the closed-form two-level optimum
    rounds_inter: int                # inter-node (leader) rounds
    rounds_intra: int                # intra-node rounds
    messages: int = 0                # point-to-point messages, all nodes
    blocks_moved: int = 0
    buffers: Optional[list] = None
    backend: Optional[str] = None


def _hier_atoms(nodes: int, cores: int, n_inter: int, n_intra: int,
                payloads: Optional[List]) -> List:
    """The message as a flat list of atoms divisible into both block
    counts (default m = n_inter * n_intra distinct ints)."""
    if payloads is None:
        return list(range(n_inter * n_intra))
    m = len(payloads)
    assert m % n_inter == 0 and m % n_intra == 0, (
        f"hier payload length {m} must divide into both n_inter={n_inter} "
        f"and n_intra={n_intra} blocks"
    )
    return list(payloads)


def _chunk(atoms: List, n: int) -> List[Tuple]:
    """Group atoms into n equal tuple-blocks (tuples compare by value in
    the flat simulators' payload checks)."""
    sz = len(atoms) // n
    return [tuple(atoms[i * sz: (i + 1) * sz]) for i in range(n)]


def _hier_default_values(nodes: int, cores: int, m: int) -> np.ndarray:
    """Seeded default contributions for the hier reductions: distinct
    int64 values, so '+' is bit-exact and duplicate/missing
    contributions shift the result.  One definition shared by
    simulate_hier_reduce and simulate_hier_allreduce (the latter's
    backend certification must regenerate the identical array)."""
    return (np.arange(nodes * cores * m, dtype=np.int64)
            .reshape(nodes, cores, m) ** 2 + 7) % 2027


def simulate_hier_broadcast(
    nodes: int,
    cores: int,
    n_inter: int,
    n_intra: int,
    root: int = 0,
    keep_buffers: bool = False,
    payloads: Optional[List] = None,
    backend: Optional[str] = None,
) -> HierSimResult:
    """Two-level broadcast: inter-node among leaders, then intra-node.

    The root's flat node-major rank is ``root = node * cores + core``.
    The message is a list of atoms (default ``n_inter * n_intra``
    distinct values) re-blocked between the levels exactly as the
    device lowering re-blocks its buffers; each flat phase re-certifies
    its own delivery, and the composed round count must equal the
    closed form :func:`repro.core.hier.hier_rounds`.  ``backend``
    additionally runs the composed host data plane and asserts every
    rank's final state bit-exact against the atoms.
    """
    from .hier import hier_host_plan, hier_rounds

    rootN, rootC = divmod(root, cores)
    atoms = _hier_atoms(nodes, cores, n_inter, n_intra, payloads)
    res = HierSimResult(
        rounds=0,
        optimal_rounds=hier_rounds("broadcast", nodes, cores, n_inter,
                                   n_intra),
        rounds_inter=0, rounds_intra=0, backend=backend,
    )
    # Phase A: the leaders (core rootC of every node) run the flat
    # inter-node broadcast of the n_inter-blocked message.
    if nodes > 1:
        a = simulate_broadcast(nodes, n_inter, root=rootN,
                               payloads=_chunk(atoms, n_inter))
        res.rounds_inter = a.rounds
        res.messages += a.messages
        res.blocks_moved += a.blocks_moved
    # Phase B: every node runs the same intra-node broadcast in
    # parallel (identical payloads after phase A -> simulate once,
    # count messages nodes times, rounds once).
    if cores > 1:
        b = simulate_broadcast(cores, n_intra, root=rootC,
                               payloads=_chunk(atoms, n_intra))
        res.rounds_intra = b.rounds
        res.messages += nodes * b.messages
        res.blocks_moved += nodes * b.blocks_moved
    res.rounds = res.rounds_inter + res.rounds_intra
    assert res.rounds == res.optimal_rounds
    assert res.rounds_inter == num_rounds(nodes, n_inter)
    assert res.rounds_intra == num_rounds(cores, n_intra)
    if backend is not None:
        vals = np.asarray(atoms)
        got = hier_host_plan("broadcast", nodes, cores, n_inter, n_intra,
                             root=root, backend=backend).run(vals)
        for j in range(nodes):
            for c in range(cores):
                assert np.array_equal(got[j, c], vals), (
                    f"{nodes}x{cores} n=({n_inter},{n_intra}) root={root}: "
                    f"{backend} hier data plane diverged at rank ({j},{c})"
                )
    if keep_buffers:
        res.buffers = [[list(atoms) for _ in range(cores)]
                       for _ in range(nodes)]
    return res


def simulate_hier_reduce(
    nodes: int,
    cores: int,
    n_inter: int,
    n_intra: int,
    root: int = 0,
    op: str = "+",
    values: Optional[np.ndarray] = None,
    keep_buffers: bool = True,
    backend: Optional[str] = None,
) -> HierSimResult:
    """Two-level reduction: intra-reduce to each leader, inter-reduce to
    the root.

    ``values`` has shape ``[nodes, cores, m]`` with ``m`` divisible by
    both block counts (a seeded int array when omitted, so '+' is
    bit-exact).  Every per-node intra simulation and the inter
    simulation carry the flat simulators' exactly-once contribution
    certificates, composing to exactly-once over all nodes*cores
    origins; the final value at the root is asserted bit-exact against
    the NumPy op-reduction over the flat rank axis.  ``backend``
    additionally certifies the composed host data plane against the
    same reference.
    """
    from .hier import hier_host_plan, hier_rounds

    _OPS[op]  # validate the op name before any sub-simulation runs
    if values is None:
        values = _hier_default_values(nodes, cores, n_inter * n_intra)
    values = np.asarray(values)
    assert values.shape[:2] == (nodes, cores)
    m = values.shape[-1] if values.ndim > 2 else 1
    values = values.reshape(nodes, cores, m)
    assert m % n_inter == 0 and m % n_intra == 0, (
        f"hier values length {m} must divide into both n_inter={n_inter} "
        f"and n_intra={n_intra} blocks"
    )
    rootN, rootC = divmod(root, cores)
    res = HierSimResult(
        rounds=0,
        optimal_rounds=hier_rounds("reduce", nodes, cores, n_inter, n_intra),
        rounds_inter=0, rounds_intra=0, backend=backend,
    )
    # Phase A: every node reduces its cores' contributions to the
    # leader (parallel across nodes: rounds counted once).
    partials = np.empty((nodes, m), values.dtype)
    if cores > 1:
        for j in range(nodes):
            a = simulate_reduce(
                cores, n_intra, root=rootC, op=op,
                values=values[j].reshape(cores, n_intra, m // n_intra),
            )
            res.rounds_intra = a.rounds
            res.messages += a.messages
            res.blocks_moved += a.blocks_moved
            partials[j] = np.stack(a.buffers[rootC]).reshape(-1)
    else:
        partials[:] = values[:, 0]
    # Phase B: the leaders reduce the node partials to the root.
    if nodes > 1:
        b = simulate_reduce(
            nodes, n_inter, root=rootN, op=op,
            values=partials.reshape(nodes, n_inter, m // n_inter),
        )
        res.rounds_inter = b.rounds
        res.messages += b.messages
        res.blocks_moved += b.blocks_moved
        final = np.stack(b.buffers[rootN]).reshape(-1)
    else:
        final = partials[0]
    res.rounds = res.rounds_inter + res.rounds_intra
    assert res.rounds == res.optimal_rounds
    # The flat certificates compose: each intra run delivered every core
    # of its node exactly once into the leader partial, the inter run
    # delivered every node partial exactly once into the root.  For the
    # order-free ops (any int '+', any 'max') the end-to-end reference
    # is exact.
    flat = values.reshape(nodes * cores, m)
    expect = np.maximum.reduce(flat) if op == "max" else np.add.reduce(flat)
    if op == "max" or np.issubdtype(values.dtype, np.integer):
        assert np.array_equal(final, expect), (
            f"{nodes}x{cores}: hier reduction diverged from the NumPy "
            f"reference"
        )
    else:
        np.testing.assert_allclose(final, expect, rtol=1e-6)
    if backend is not None:
        got = hier_host_plan("reduce", nodes, cores, n_inter, n_intra,
                             root=root, op=op, backend=backend).run(values)
        assert np.array_equal(got, final), (
            f"{nodes}x{cores} n=({n_inter},{n_intra}) root={root} op={op}: "
            f"{backend} hier data plane diverged from the reference"
        )
    res.buffers = [final] if keep_buffers else None
    return res


def simulate_hier_allreduce(
    nodes: int,
    cores: int,
    n_inter: int,
    n_intra: int,
    root: int = 0,
    op: str = "+",
    values: Optional[np.ndarray] = None,
    keep_buffers: bool = True,
    backend: Optional[str] = None,
) -> HierSimResult:
    """Two-level all-reduction: intra-reduce -> inter-allreduce among
    the leaders -> intra-broadcast fan-out, ``2(n_C-1+q_C) +
    2(n_N-1+q_N)`` composed rounds.  The return path re-runs the
    payload-checked broadcast simulations carrying the reduced blocks,
    so every rank provably ends with the composed op-reduction;
    ``backend`` certifies the composed data plane of all four sweeps.
    """
    from .hier import hier_host_plan, hier_rounds

    red = simulate_hier_reduce(
        nodes, cores, n_inter, n_intra, root=root, op=op, values=values,
        keep_buffers=True, backend=None,
    )
    res = HierSimResult(
        rounds=red.rounds,
        optimal_rounds=hier_rounds("allreduce", nodes, cores, n_inter,
                                   n_intra),
        rounds_inter=red.rounds_inter,
        rounds_intra=red.rounds_intra,
        messages=red.messages,
        blocks_moved=red.blocks_moved,
        backend=backend,
    )
    reduced = list(red.buffers[0])
    rootN, rootC = divmod(root, cores)
    # Return path: inter broadcast among leaders, intra fan-out -- both
    # carry the reduced payload through the content-checked simulator.
    if nodes > 1:
        b1 = simulate_broadcast(nodes, n_inter, root=rootN,
                                payloads=_chunk(reduced, n_inter))
        res.rounds_inter += b1.rounds
        res.messages += b1.messages
        res.blocks_moved += b1.blocks_moved
    if cores > 1:
        b2 = simulate_broadcast(cores, n_intra, root=rootC,
                                payloads=_chunk(reduced, n_intra))
        res.rounds_intra += b2.rounds
        res.messages += nodes * b2.messages
        res.blocks_moved += nodes * b2.blocks_moved
    res.rounds = res.rounds_inter + res.rounds_intra
    assert res.rounds == res.optimal_rounds
    if backend is not None:
        vals = values
        if vals is None:
            vals = _hier_default_values(nodes, cores, n_inter * n_intra)
        vals = np.asarray(vals).reshape(nodes, cores, -1)
        got = hier_host_plan("allreduce", nodes, cores, n_inter, n_intra,
                             root=root, op=op, backend=backend).run(vals)
        expect = np.asarray(reduced).reshape(-1)
        for j in range(nodes):
            for c in range(cores):
                assert np.array_equal(got[j, c], expect), (
                    f"{nodes}x{cores} n=({n_inter},{n_intra}) op={op}: "
                    f"{backend} hier data plane diverged at rank ({j},{c})"
                )
    res.buffers = [reduced] if keep_buffers else None
    return res
