"""JAX circulant-graph collectives driven by the paper's schedules.

TPU-native adaptation of Algorithm 1 (broadcast) and Algorithm 2
(all-to-all broadcast / allgatherv): each communication round
``Send(t^k) || Recv(f^k)`` on the circulant graph is one
``jax.lax.ppermute`` with the static rotation ``r -> (r + skip[k]) % p``.
The per-rank receive/send block indices come from the O(log p) schedule
algorithms via the cached engine bundle (:mod:`repro.core.engine`):
small [p, q] integer tables (total host cost O(p log p), i.e. O(log p)
per participating device, paid once per process for each (p, root))
looked up with the device's own ``axis_index`` at run time, so the
traced program is identical on every device (SPMD).

Hardware adaptation notes (see DESIGN.md):
  * the paper's one-ported bidirectional model maps to one ppermute per
    round: every chip sends and receives exactly one block per round;
  * skips are arbitrary rotations; on a TPU torus a rotation by s costs
    multiple ICI hops, so the roofline collective term counts the
    *bytes x rounds* while the latency term counts rounds (the paper's
    metric).  On pod-interconnect/DCN (where broadcast/allgatherv of
    checkpoints and irregular activations actually happen) rotations are
    switch-routed and the paper's model applies directly.

Negative block indices ("neither sent nor received") are realized with a
garbage slot: buffers carry n+1 block slots, index n is scratch.  By
Correctness Condition 1 the sender's block index is negative exactly when
the receiver's is, so both sides address the garbage slot in the same
round and no masking is needed.  Indices > n-1 are capped to n-1 (final
phase), exactly as in the paper.

Data plane: the per-round pack/exchange/unpack-or-accumulate step runs
through the pluggable :class:`repro.core.roundstep.RoundStep` backend --
``backend="jnp"`` (default, pure-jnp gathers/scatters, lowers anywhere)
or ``backend="pallas"`` (fused scalar-prefetch kernels, the TPU fast
path; interpret-mode on CPU).  Slot selection is precomputed host-side
from the engine's per-round tables, so the traced per-round work is one
``ppermute`` plus one backend call.  Both backends are bit-exact against
each other and against the simulator reference (see docs/kernels.md).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .costmodel import (
    CommModel,
    optimal_num_blocks_allgather,
    optimal_num_blocks_bcast,
    optimal_num_blocks_reduce,
)
from .engine import ScheduleBundle, get_bundle
from .jaxcompat import shard_map as _shard_map
from .roundstep import (
    broadcast_slot_plan,
    get_round_step,
    reduce_slot_plan,
)

__all__ = [
    "circulant_broadcast",
    "circulant_allgather",
    "circulant_allgatherv",
    "circulant_allbroadcast",
    "circulant_reduce",
    "circulant_allreduce",
    "ring_allgather",
    "CirculantTables",
    "build_tables",
]


# Seed-compat names: the schedule constants now live in the cached
# engine bundle (root relabeling, batched tables, round plans included).
# Both old entry points -- CirculantTables(p) and build_tables(p) --
# resolve to the cached bundle.
def CirculantTables(p: int) -> ScheduleBundle:  # noqa: N802 - legacy class name
    """Deprecated alias for :func:`repro.core.engine.get_bundle`."""
    return get_bundle(p)


def build_tables(p: int) -> ScheduleBundle:
    """Deprecated alias for :func:`repro.core.engine.get_bundle`."""
    return get_bundle(p)


def _rot_perm(p: int, s: int):
    """Static ppermute pairs for the rotation r -> (r + s) % p."""
    return [(r, (r + s) % p) for r in range(p)]


def _split_blocks(flat: jnp.ndarray, n: int):
    """Split a flat vector into n padded blocks + 1 garbage slot: [n+1, B]."""
    size = flat.shape[0]
    bs = -(-size // n)  # ceil
    pad = n * bs - size
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n, bs)
    garbage = jnp.zeros((1, bs), flat.dtype)
    return jnp.concatenate([blocks, garbage], axis=0), bs, pad


# --------------------------------------------------------------- broadcast


def circulant_broadcast(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    backend: str = "jnp",
    model: CommModel = CommModel(),
):
    """Round-optimal n-block broadcast of ``x[root]`` along a mesh axis.

    ``x`` has a leading axis of size p sharded over ``axis_name`` (each
    rank owns one slice; only the root's slice content matters).  Returns
    an array of the same spec where every slice equals ``x[root]``.
    Runs in n-1+ceil(log2 p) ppermute rounds (Algorithm 1) -- the
    paper's lower bound for n-block broadcast in the one-ported
    bidirectional model, so the round count is optimal.

    ``backend`` selects the per-round data plane ("jnp" or "pallas"),
    see :mod:`repro.core.roundstep`; per-round buffer slots are
    precomputed host-side from the engine's per-round tables, so every
    traced round is one ppermute plus one fused round-step call.
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    # Rooted bundle: rows are indexed by real rank, relabeling done once
    # in the engine (no per-call-site modulo arithmetic).
    bundle = get_bundle(p, root)
    per = x.shape[0] // p if x.shape[0] % p == 0 else None
    if per != 1:
        raise ValueError("x must have leading axis == axis size (one slice/rank)")
    elems = int(np.prod(x.shape[1:]))
    n = n_blocks or max(1, optimal_num_blocks_bcast(p, elems * x.dtype.itemsize, model))
    n = min(n, max(1, elems))
    recv_slots, send_slots, ks = broadcast_slot_plan(bundle, n)
    step = get_round_step(backend)
    R = len(ks)

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)
        buf, bs, pad = _split_blocks(flat, n)
        buf = jnp.where(r == root, buf, jnp.zeros_like(buf))[None]  # [1, n+1, bs]
        recv_t = jnp.asarray(recv_slots)  # [R, p] static slot tables
        send_t = jnp.asarray(send_slots)
        msg = step.pack(buf, send_t[0, r][None])
        for t in range(R):
            got = jax.lax.ppermute(
                msg, axis_name, _rot_perm(p, bundle.skip[int(ks[t])])
            )
            if t + 1 < R:
                buf, msg = step.shuffle(
                    buf, got, recv_t[t, r][None], send_t[t + 1, r][None]
                )
            else:
                buf = step.unpack(buf, got, recv_t[t, r][None])
        out = buf[0, :n].reshape(-1)[: flat.shape[0]]
        return out.reshape(xs.shape)

    shard = _shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
        # jax has no replication rule for pallas_call inside shard_map.
        check_vma=(backend == "jnp"),
    )
    return shard(x)


# --------------------------------------------------------------- allgather


def circulant_allgather(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = CommModel(),
):
    """All-to-all broadcast (regular allgather) along a mesh axis.

    ``x``: global array sharded on its leading dim over ``axis_name``.
    Returns the fully replicated gathered array (same global shape,
    spec ()) in the optimal n-1+ceil(log2 p) rounds.  This is
    Algorithm 2 with equal-size contributions; the per-round message
    packs one block per root (p-1 useful + 1 garbage row kept for a
    uniform [p, B] layout).  ``backend`` selects the per-round data
    plane as in :func:`circulant_broadcast` -- here the p root rows map
    onto the batched round-step kernel rows directly.
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    bundle = get_bundle(p)
    if x.shape[0] % p != 0:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by axis size {p}")
    shard_elems = int(np.prod(x.shape[1:])) * (x.shape[0] // p)
    nbytes = shard_elems * x.dtype.itemsize * p
    n = n_blocks or max(1, optimal_num_blocks_allgather(p, nbytes, model))
    n = min(n, max(1, shard_elems))
    # One clamped [R, p] slot table serves recv AND send: by Condition 2
    # the send slot of root row j is the recv slot of the shifted
    # virtual rank, so both are gathers of the same table.
    recv_slots, _, ks = broadcast_slot_plan(bundle, n)
    step = get_round_step(backend)
    R = len(ks)
    jidx = jnp.arange(p)

    def body(xs):
        # xs: this rank's shard with leading dim x.shape[0]//p
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)
        own, bs, pad = _split_blocks(flat, n)  # [n+1, bs]
        # buffers[j]: blocks of root j; own row filled, others zero.
        buf = jnp.zeros((p, n + 1, bs), xs.dtype)
        buf = jax.lax.dynamic_update_slice(buf, own[None], (r, 0, 0))
        S = jnp.asarray(recv_slots)  # [R, p] static slot table
        base = (r - jidx) % p        # virtual rank of root row j at rank r

        def send_slots_at(t):
            return S[t][(base + bundle.skip[int(ks[t])]) % p]

        msg = step.pack(buf, send_slots_at(0))
        for t in range(R):
            got = jax.lax.ppermute(
                msg, axis_name, _rot_perm(p, bundle.skip[int(ks[t])])
            )
            if t + 1 < R:
                buf, msg = step.shuffle(buf, got, S[t][base], send_slots_at(t + 1))
            else:
                buf = step.unpack(buf, got, S[t][base])
        out = buf[:, :n, :].reshape(p, -1)[:, : flat.shape[0]]
        return out.reshape((x.shape[0],) + x.shape[1:])

    shard = _shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(),
        check_vma=False,  # result is replicated by construction
    )
    return shard(x)


def circulant_allgatherv(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    sizes: Sequence[int],
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = CommModel(),
):
    """Irregular allgather (MPI_Allgatherv analogue), Algorithm 2 proper.

    ``x``: [p, cap] sharded over ``axis_name``; rank j's contribution is
    x[j, :sizes[j]] (the rest is padding).  Sizes are static.  Every rank
    divides its contribution into n blocks of (static, per-rank) size
    ceil(sizes[j]/n); the per-round message concatenates one block per
    root, so the wire volume tracks sum(sizes), not p*max(sizes) --
    this is what makes the degenerate case fast (paper Figure 2).
    Returns the replicated [p, cap] array with row j = rank j's data.

    Block sizes are ragged per root, so the data plane uses the
    round-step ``pack``/``unpack`` primitives per root row (the fused
    shuffle needs a uniform message layout).  With ``backend="pallas"``
    that means 2p single-row kernel launches per round -- correct and
    tested, but launch overhead dominates the tiny copies, so prefer
    the default ``"jnp"`` backend for ragged sizes.
    """
    p = mesh.shape[axis_name]
    sizes = [int(s) for s in sizes]
    assert len(sizes) == p
    if p == 1:
        return x
    bundle = get_bundle(p)
    total = sum(sizes)
    n = n_blocks or max(
        1, optimal_num_blocks_allgather(p, max(total, 1) * x.dtype.itemsize, model)
    )
    n = min(n, max(1, min([s for s in sizes if s > 0], default=1)))
    bs_j = [max(1, -(-sizes[j] // n)) for j in range(p)]  # per-root block size
    recv_slots, _, ks = broadcast_slot_plan(bundle, n)
    step = get_round_step(backend)
    R = len(ks)
    cap = x.shape[-1]

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)  # [cap], own contribution padded to cap
        # Per-root buffers with static per-root block sizes (+ garbage slot).
        bufs: List[jnp.ndarray] = []
        for j in range(p):
            pj = jnp.pad(flat[: min(cap, n * bs_j[j])],
                         (0, max(0, n * bs_j[j] - cap)))
            own = jnp.concatenate(
                [pj[: n * bs_j[j]].reshape(n, bs_j[j]),
                 jnp.zeros((1, bs_j[j]), xs.dtype)], axis=0)
            bufs.append(jnp.where(r == j, own, jnp.zeros_like(own)))
        S = jnp.asarray(recv_slots)  # [R, p] static slot table
        for t in range(R):
            sk = bundle.skip[int(ks[t])]
            parts = []
            slots_r = []
            for j in range(p):
                ss = S[t][(r - j + sk) % p]
                rs = S[t][(r - j) % p]
                parts.append(step.pack(bufs[j][None], ss[None])[0])
                slots_r.append(rs)
            msg = jnp.concatenate(parts)  # [sum bs_j]
            got = jax.lax.ppermute(msg, axis_name, _rot_perm(p, sk))
            o = 0
            for j in range(p):
                piece = got[o : o + bs_j[j]][None]
                bufs[j] = step.unpack(bufs[j][None], piece, slots_r[j][None])[0]
                o += bs_j[j]
        rows = []
        for j in range(p):
            rj = bufs[j][:n].reshape(-1)[: sizes[j]]
            rows.append(jnp.pad(rj, (0, cap - sizes[j])))
        return jnp.stack(rows)

    shard = _shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(), check_vma=False
    )
    return shard(x)


# ---------------------------------------------------- reduce-scatter (NEW)


def circulant_reduce_scatter(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = CommModel(),
):
    """BEYOND-PAPER: round-optimal reduce-scatter by *time reversal* of the
    circulant all-to-all broadcast (allgather and reduce-scatter are dual
    collectives; reversing every round of Algorithm 2 -- negated
    rotations, send-what-you-received, accumulate-what-you-sent -- yields
    an n-1+ceil(log2 p)-round reduce-scatter on the same schedules).

    ``x``: [p, L] sharded on dim 0 over ``axis_name``; row r is rank r's
    full L-length contribution with L = p * shard.  Returns [p, shard]
    sharded the same way: row r = sum_r' x[r'] restricted to shard r.

    Capped block indices (> n-1) are real deliveries for small n; the
    reversal routes them with drain-after-send so every contribution
    reaches its root exactly once (verified for all p<=100 x n<=13 in
    tests).
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    bundle = get_bundle(p)
    L = x.shape[1]
    if L % p != 0:
        raise ValueError(f"row length {L} not divisible by p={p}")
    shard = L // p
    n = n_blocks or max(
        1, optimal_num_blocks_allgather(p, L * x.dtype.itemsize, model)
    )
    n = min(n, max(1, shard))
    # Clamped reversed per-round tables (same single recv-derived table
    # for forward-capture and accumulate slots -- Condition 2 again).
    fwd_eff, acc_eff, ks = bundle.reversed_per_round_tables(n)
    fwd_slots = np.where(fwd_eff < 0, n, np.minimum(fwd_eff, n - 1)).astype(np.int32)
    acc_slots = np.where(acc_eff < 0, n, np.minimum(acc_eff, n - 1)).astype(np.int32)
    step = get_round_step(backend)
    R = len(ks)
    jidx = jnp.arange(p)

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        # partials per root j: [p, n+1, bs] (slot n = garbage)
        rows = xs[0].reshape(p, shard)              # contribution per root
        bs = -(-shard // n)
        pad = n * bs - shard
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
        buf = jnp.concatenate(
            [rows.reshape(p, n, bs), jnp.zeros((p, 1, bs), xs.dtype)], axis=1
        ).astype(jnp.float32)
        F = jnp.asarray(fwd_slots)  # [R, p] static slot tables
        A = jnp.asarray(acc_slots)
        base = (r - jidx) % p
        garbage = jnp.full((p,), n, jnp.int32)
        # Initial capture+drain of round 0's forwarded partials (the acc
        # part folds a zero message into the garbage slots -- a no-op).
        buf, msg = step.acc_shuffle(
            buf, jnp.zeros((p, bs), buf.dtype), garbage, F[0][base], op="sum"
        )
        for t in range(R):
            sk = bundle.skip[int(ks[t])]
            got = jax.lax.ppermute(msg, axis_name, _rot_perm(p, p - sk % p))
            nxt = F[t + 1][base] if t + 1 < R else garbage
            # accumulate round t's incoming partials, then capture+drain
            # round t+1's forwards (drain-after-send: each partial flows
            # along exactly one tree edge).
            buf, msg = step.acc_shuffle(buf, got, A[t][base], nxt, op="sum")
        own = jax.lax.dynamic_slice(buf, (r, 0, 0), (1, n, bs))
        out = own.reshape(-1)[:shard].astype(xs.dtype)
        return out[None]

    shard_fn = _shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
        check_vma=(backend == "jnp"),
    )
    return shard_fn(x)


# ------------------------------------- reversed-schedule collective family
#
# The recv/send schedules are time-reversible (Träff, arXiv:2407.18004):
# replaying the broadcast rounds backwards (t -> R-1-t) with every edge
# flipped turns the round-optimal broadcast into a round-optimal
# *reduction*, and composing reduction + broadcast yields all-reduction
# in 2(n-1)+2*ceil(log2 p) rounds.  The reversed tables come from the
# same cached bundle (engine rev_recv/rev_send: the forward tables with
# roles swapped -- no second table build).


def circulant_reduce(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    op: str = "sum",
    backend: str = "jnp",
    model: CommModel = CommModel(),
):
    """Round-optimal n-block reduction to ``root`` (reversed Algorithm 1).

    ``x`` has a leading axis of size p sharded over ``axis_name`` (each
    rank owns one slice).  Returns an array of the same spec where the
    root's slice is the elementwise op-reduction of all slices and every
    other slice is zero.  Runs in the optimal ``n-1+ceil(log2 p)``
    ppermute rounds -- the time reversal of the broadcast
    (arXiv:2407.18004) inherits the forward schedule's optimal round
    count and satisfies the reversed Correctness Conditions 3-4 checked
    by ``verify_reversed_schedules``: the reversed round for forward round
    (k, off) sends the partial of the forward-*received* block to the
    forward from-neighbor (rotation by -skip[k]) and accumulates the
    incoming partial into the forward-*sent* block.

    Partials are drained after each forward (capture - drain -
    accumulate), so final-phase capped re-sends move an already-emptied
    (identity) partial and every contribution reaches the root exactly
    once -- which makes ``op="sum"`` bit-exact, not just ``"max"``.
    Buffers carry n+2 slots: slot n is garbage, slot n+1 pins the op
    identity so the root (which never forwards a live partial) always
    ships the identity.  ``backend`` selects the per-round data plane
    ("jnp" or "pallas": the fused accumulate+capture/drain kernel), see
    :mod:`repro.core.roundstep`.
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    # Combine/identity semantics shared with the kernels and the jnp
    # oracle -- one registry, so drained slots and the identity slot the
    # data plane ships agree bit-for-bit (validates op before tracing).
    from repro.kernels.reduce_ops import op_identity

    bundle = get_bundle(p, root)
    if x.shape[0] != p:
        raise ValueError("x must have leading axis == axis size (one slice/rank)")
    elems = int(np.prod(x.shape[1:]))
    n = n_blocks or max(1, optimal_num_blocks_reduce(p, elems * x.dtype.itemsize, model))
    n = min(n, max(1, elems))
    fwd_slots, acc_slots, ks = reduce_slot_plan(bundle, n)
    step = get_round_step(backend)
    R = len(ks)
    ident = op_identity(op, x.dtype)

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)
        buf, bs, pad = _split_blocks(flat, n)     # [n+1, bs]: data + garbage
        buf = jnp.concatenate(
            [buf, jnp.full((1, bs), ident, buf.dtype)], axis=0
        )[None]                                   # [1, n+2, bs]: + identity slot
        F = jnp.asarray(fwd_slots)  # [R, p] static slot tables (root row
        A = jnp.asarray(acc_slots)  # pinned to the identity slot n+1)
        garbage = jnp.full((1,), n, jnp.int32)
        # Initial capture+drain of round 0's forwarded partial.
        buf, msg = step.acc_shuffle(
            buf, jnp.zeros((1, bs), buf.dtype), garbage, F[0, r][None], op=op
        )
        for t in range(R):
            got = jax.lax.ppermute(
                msg, axis_name, _rot_perm(p, (p - bundle.skip[int(ks[t])]) % p)
            )
            nxt = F[t + 1, r][None] if t + 1 < R else garbage
            buf, msg = step.acc_shuffle(buf, got, A[t, r][None], nxt, op=op)
        out = buf[0, :n].reshape(-1)[: flat.shape[0]].reshape(xs.shape)
        return jnp.where(r == root, out, jnp.zeros_like(out))

    shard = _shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
        check_vma=(backend == "jnp"),
    )
    return shard(x)


def circulant_allreduce(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    op: str = "sum",
    backend: str = "jnp",
    model: CommModel = CommModel(),
):
    """All-reduction in the composed ``2(n-1)+2*ceil(log2 p)`` rounds.

    Reduce to ``root`` on the reversed schedule, then broadcast the
    result back on the forward schedule (the reduce+broadcast
    composition of arXiv:2407.18004) -- both phases run on the same
    cached ``get_bundle(p, root)`` tables and the same block count n,
    so the composition is exactly twice the optimal single-collective
    round count ``n-1+ceil(log2 p)``.
    ``x`` is [p, ...] sharded over ``axis_name``; every output slice
    equals the elementwise op-reduction (``"sum"`` or ``"max"``, exact
    per the capture-drain-accumulate rule of :func:`circulant_reduce`)
    of all input slices.  ``backend`` selects the per-round data plane
    for both phases ("jnp" or "pallas").
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    if x.shape[0] != p:
        raise ValueError("x must have leading axis == axis size (one slice/rank)")
    elems = int(np.prod(x.shape[1:]))
    n = n_blocks or max(1, optimal_num_blocks_reduce(p, elems * x.dtype.itemsize, model))
    n = min(n, max(1, elems))
    red = circulant_reduce(
        mesh, axis_name, x, n_blocks=n, root=root, op=op, backend=backend,
        model=model,
    )
    return circulant_broadcast(
        mesh, axis_name, red, n_blocks=n, root=root, backend=backend, model=model
    )


def circulant_allbroadcast(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = CommModel(),
):
    """All-broadcast: every rank's slice reaches every rank in the
    optimal ``n-1+ceil(log2 p)`` rounds.

    The collective-family name (arXiv:2407.18004) for the all-to-all
    broadcast of Algorithm 2; identical to :func:`circulant_allgather`
    -- each rank acts as the root of its own forward broadcast, all p
    interleaved on the same circulant graph with one packed message per
    round, so the round count matches the single-root broadcast.
    ``backend`` selects the per-round data plane ("jnp" or "pallas").
    """
    return circulant_allgather(
        mesh, axis_name, x, n_blocks=n_blocks, backend=backend, model=model
    )


# ----------------------------------------------------------- ring baseline


def ring_allgather(mesh: Mesh, axis_name: str, x: jax.Array):
    """Classic p-1 round ring allgather baseline (bandwidth-optimal,
    latency p-1 rounds vs the circulant's n-1+ceil(log2 p))."""
    p = mesh.shape[axis_name]
    if p == 1:
        return x

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        parts = [(r, xs)]
        cur = xs
        for _ in range(p - 1):
            cur = jax.lax.ppermute(cur, axis_name, _rot_perm(p, 1))
            parts.append((None, cur))
        # piece i came from rank (r - i) % p; place rows by origin
        buf = jnp.zeros((p,) + xs.shape, xs.dtype)
        cur = xs
        buf = jax.lax.dynamic_update_slice(buf, xs[None], (r,) + (0,) * xs.ndim)
        for i in range(1, p):
            cur = parts[i][1]
            src = (r - i) % p
            buf = jax.lax.dynamic_update_slice(buf, cur[None], (src,) + (0,) * xs.ndim)
        return buf.reshape((p * xs.shape[0],) + xs.shape[1:])

    shard = _shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(), check_vma=False
    )
    return shard(x)
