"""JAX circulant-graph collectives driven by the paper's schedules.

TPU-native adaptation of Algorithm 1 (broadcast) and Algorithm 2
(all-to-all broadcast / allgatherv): each communication round
``Send(t^k) || Recv(f^k)`` on the circulant graph is one
``jax.lax.ppermute`` with the static rotation ``r -> (r + skip[k]) % p``.
The per-rank receive/send block indices come from the O(log p) schedule
algorithms via the cached engine bundle (:mod:`repro.core.engine`):
small [p, q] integer tables (total host cost O(p log p), i.e. O(log p)
per participating device, paid once per process for each (p, root))
looked up with the device's own ``axis_index`` at run time, so the
traced program is identical on every device (SPMD).

Hardware adaptation notes (see DESIGN.md):
  * the paper's one-ported bidirectional model maps to one ppermute per
    round: every chip sends and receives exactly one block per round;
  * skips are arbitrary rotations; on a TPU torus a rotation by s costs
    multiple ICI hops, so the roofline collective term counts the
    *bytes x rounds* while the latency term counts rounds (the paper's
    metric).  On pod-interconnect/DCN (where broadcast/allgatherv of
    checkpoints and irregular activations actually happen) rotations are
    switch-routed and the paper's model applies directly.

Negative block indices ("neither sent nor received") are realized with a
garbage slot: buffers carry n+1 block slots, index n is scratch.  By
Correctness Condition 1 the sender's block index is negative exactly when
the receiver's is, so both sides address the garbage slot in the same
round and no masking is needed.  Indices > n-1 are capped to n-1 (final
phase), exactly as in the paper.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .costmodel import (
    CommModel,
    optimal_num_blocks_allgather,
    optimal_num_blocks_bcast,
    optimal_num_blocks_reduce,
)
from .engine import ScheduleBundle, get_bundle
from .jaxcompat import shard_map as _shard_map

__all__ = [
    "circulant_broadcast",
    "circulant_allgather",
    "circulant_allgatherv",
    "circulant_allbroadcast",
    "circulant_reduce",
    "circulant_allreduce",
    "ring_allgather",
    "CirculantTables",
    "build_tables",
]


# Seed-compat names: the schedule constants now live in the cached
# engine bundle (root relabeling, batched tables, round plans included).
# Both old entry points -- CirculantTables(p) and build_tables(p) --
# resolve to the cached bundle.
def CirculantTables(p: int) -> ScheduleBundle:  # noqa: N802 - legacy class name
    """Deprecated alias for :func:`repro.core.engine.get_bundle`."""
    return get_bundle(p)


def build_tables(p: int) -> ScheduleBundle:
    """Deprecated alias for :func:`repro.core.engine.get_bundle`."""
    return get_bundle(p)


def _rot_perm(p: int, s: int):
    """Static ppermute pairs for the rotation r -> (r + s) % p."""
    return [(r, (r + s) % p) for r in range(p)]


def _split_blocks(flat: jnp.ndarray, n: int):
    """Split a flat vector into n padded blocks + 1 garbage slot: [n+1, B]."""
    size = flat.shape[0]
    bs = -(-size // n)  # ceil
    pad = n * bs - size
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n, bs)
    garbage = jnp.zeros((1, bs), flat.dtype)
    return jnp.concatenate([blocks, garbage], axis=0), bs, pad


# --------------------------------------------------------------- broadcast


def circulant_broadcast(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    model: CommModel = CommModel(),
):
    """Round-optimal n-block broadcast of ``x[root]`` along a mesh axis.

    ``x`` has a leading axis of size p sharded over ``axis_name`` (each
    rank owns one slice; only the root's slice content matters).  Returns
    an array of the same spec where every slice equals ``x[root]``.
    Runs in n-1+ceil(log2 p) ppermute rounds (Algorithm 1).
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    # Rooted bundle: rows are indexed by real rank, relabeling done once
    # in the engine (no per-call-site modulo arithmetic).
    bundle = get_bundle(p, root)
    per = x.shape[0] // p if x.shape[0] % p == 0 else None
    if per != 1:
        raise ValueError("x must have leading axis == axis size (one slice/rank)")
    elems = int(np.prod(x.shape[1:]))
    n = n_blocks or max(1, optimal_num_blocks_bcast(p, elems * x.dtype.itemsize, model))
    n = min(n, max(1, elems))
    recv_t, send_t = bundle.jnp_tables()
    rounds = bundle.round_plan(n)

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)
        buf, bs, pad = _split_blocks(flat, n)
        buf = jnp.where(r == root, buf, jnp.zeros_like(buf))
        my_recv = recv_t[r]  # [q]
        my_send = send_t[r]
        for (k, off) in rounds:
            sb = my_send[k] + off
            rb = my_recv[k] + off
            send_slot = jnp.where(sb < 0, n, jnp.minimum(sb, n - 1))
            recv_slot = jnp.where(rb < 0, n, jnp.minimum(rb, n - 1))
            out_blk = jax.lax.dynamic_slice_in_dim(buf, send_slot, 1, axis=0)
            got = jax.lax.ppermute(out_blk, axis_name, _rot_perm(p, bundle.skip[k]))
            buf = jax.lax.dynamic_update_slice_in_dim(buf, got, recv_slot, axis=0)
        out = buf[:n].reshape(-1)[: flat.shape[0]]
        return out.reshape(xs.shape)

    shard = _shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
    )
    return shard(x)


# --------------------------------------------------------------- allgather


def circulant_allgather(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    model: CommModel = CommModel(),
):
    """All-to-all broadcast (regular allgather) along a mesh axis.

    ``x``: global array sharded on its leading dim over ``axis_name``.
    Returns the fully replicated gathered array (same global shape,
    spec ()).  This is Algorithm 2 with equal-size contributions; the
    per-round message packs one block per root (p-1 useful + 1 garbage
    row kept for a uniform [p, B] layout).
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    bundle = get_bundle(p)
    if x.shape[0] % p != 0:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by axis size {p}")
    shard_elems = int(np.prod(x.shape[1:])) * (x.shape[0] // p)
    nbytes = shard_elems * x.dtype.itemsize * p
    n = n_blocks or max(1, optimal_num_blocks_allgather(p, nbytes, model))
    n = min(n, max(1, shard_elems))
    recv_t = jnp.asarray(bundle.recv)  # [p, q]
    rounds = bundle.round_plan(n)
    jidx = jnp.arange(p)

    def body(xs):
        # xs: this rank's shard with leading dim x.shape[0]//p
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)
        own, bs, pad = _split_blocks(flat, n)  # [n+1, bs]
        # buffers[j]: blocks of root j; own row filled, others zero.
        buf = jnp.zeros((p, n + 1, bs), xs.dtype)
        buf = jax.lax.dynamic_update_slice(buf, own[None], (r, 0, 0))
        for (k, off) in rounds:
            sk = bundle.skip[k]
            # recvblocks_r[j][k] = recv[(r - j) % p][k]
            rb = recv_t[(r - jidx) % p, k] + off
            # sendblocks_r[j][k] = recv[(r - j + skip[k]) % p][k]
            sb = recv_t[(r - jidx + sk) % p, k] + off
            send_slot = jnp.where(sb < 0, n, jnp.minimum(sb, n - 1))
            recv_slot = jnp.where(rb < 0, n, jnp.minimum(rb, n - 1))
            msg = jnp.take_along_axis(buf, send_slot[:, None, None], axis=1)
            got = jax.lax.ppermute(msg, axis_name, _rot_perm(p, sk))
            buf = jax.lax.scatter(
                buf,
                jnp.stack([jidx, recv_slot], axis=-1),
                got[:, 0, :],
                jax.lax.ScatterDimensionNumbers(
                    update_window_dims=(1,),
                    inserted_window_dims=(0, 1),
                    scatter_dims_to_operand_dims=(0, 1),
                ),
                mode="promise_in_bounds",
            )
        out = buf[:, :n, :].reshape(p, -1)[:, : flat.shape[0]]
        return out.reshape((x.shape[0],) + x.shape[1:])

    shard = _shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(),
        check_vma=False,  # result is replicated by construction
    )
    return shard(x)


def circulant_allgatherv(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    sizes: Sequence[int],
    *,
    n_blocks: Optional[int] = None,
    model: CommModel = CommModel(),
):
    """Irregular allgather (MPI_Allgatherv analogue), Algorithm 2 proper.

    ``x``: [p, cap] sharded over ``axis_name``; rank j's contribution is
    x[j, :sizes[j]] (the rest is padding).  Sizes are static.  Every rank
    divides its contribution into n blocks of (static, per-rank) size
    ceil(sizes[j]/n); the per-round message concatenates one block per
    root, so the wire volume tracks sum(sizes), not p*max(sizes) --
    this is what makes the degenerate case fast (paper Figure 2).
    Returns the replicated [p, cap] array with row j = rank j's data.
    """
    p = mesh.shape[axis_name]
    sizes = [int(s) for s in sizes]
    assert len(sizes) == p
    if p == 1:
        return x
    bundle = get_bundle(p)
    total = sum(sizes)
    n = n_blocks or max(
        1, optimal_num_blocks_allgather(p, max(total, 1) * x.dtype.itemsize, model)
    )
    n = min(n, max(1, min([s for s in sizes if s > 0], default=1)))
    bs_j = [max(1, -(-sizes[j] // n)) for j in range(p)]  # per-root block size
    recv_t = jnp.asarray(bundle.recv)
    rounds = bundle.round_plan(n)
    cap = x.shape[-1]

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)  # [cap], own contribution padded to cap
        # Per-root buffers with static per-root block sizes (+ garbage slot).
        bufs: List[jnp.ndarray] = []
        for j in range(p):
            pj = jnp.pad(flat[: min(cap, n * bs_j[j])],
                         (0, max(0, n * bs_j[j] - cap)))
            own = jnp.concatenate(
                [pj[: n * bs_j[j]].reshape(n, bs_j[j]),
                 jnp.zeros((1, bs_j[j]), xs.dtype)], axis=0)
            bufs.append(jnp.where(r == j, own, jnp.zeros_like(own)))
        for (k, off) in rounds:
            sk = bundle.skip[k]
            parts = []
            slots_r = []
            for j in range(p):
                sb = recv_t[(r - j + sk) % p, k] + off
                rb = recv_t[(r - j) % p, k] + off
                ss = jnp.where(sb < 0, n, jnp.minimum(sb, n - 1))
                rs = jnp.where(rb < 0, n, jnp.minimum(rb, n - 1))
                parts.append(jax.lax.dynamic_slice_in_dim(bufs[j], ss, 1, 0)[0])
                slots_r.append(rs)
            msg = jnp.concatenate(parts)  # [sum bs_j]
            got = jax.lax.ppermute(msg, axis_name, _rot_perm(p, sk))
            o = 0
            for j in range(p):
                piece = got[o : o + bs_j[j]][None]
                bufs[j] = jax.lax.dynamic_update_slice_in_dim(
                    bufs[j], piece, slots_r[j], 0
                )
                o += bs_j[j]
        rows = []
        for j in range(p):
            rj = bufs[j][:n].reshape(-1)[: sizes[j]]
            rows.append(jnp.pad(rj, (0, cap - sizes[j])))
        return jnp.stack(rows)

    shard = _shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(), check_vma=False
    )
    return shard(x)


# ---------------------------------------------------- reduce-scatter (NEW)


def circulant_reduce_scatter(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    model: CommModel = CommModel(),
):
    """BEYOND-PAPER: round-optimal reduce-scatter by *time reversal* of the
    circulant all-to-all broadcast (allgather and reduce-scatter are dual
    collectives; reversing every round of Algorithm 2 -- negated
    rotations, send-what-you-received, accumulate-what-you-sent -- yields
    an n-1+ceil(log2 p)-round reduce-scatter on the same schedules).

    ``x``: [p, L] sharded on dim 0 over ``axis_name``; row r is rank r's
    full L-length contribution with L = p * shard.  Returns [p, shard]
    sharded the same way: row r = sum_r' x[r'] restricted to shard r.

    Capped block indices (> n-1) are real deliveries for small n; the
    reversal routes them with drain-after-send so every contribution
    reaches its root exactly once (verified for all p<=100 x n<=13 in
    tests).
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    bundle = get_bundle(p)
    L = x.shape[1]
    if L % p != 0:
        raise ValueError(f"row length {L} not divisible by p={p}")
    shard = L // p
    n = n_blocks or max(
        1, optimal_num_blocks_allgather(p, L * x.dtype.itemsize, model)
    )
    n = min(n, max(1, shard))
    recv_t = jnp.asarray(bundle.recv)
    rounds = bundle.round_plan(n)
    jidx = jnp.arange(p)

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        # partials per root j: [p, n+1, bs] (slot n = garbage)
        rows = xs[0].reshape(p, shard)              # contribution per root
        bs = -(-shard // n)
        pad = n * bs - shard
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
        buf = jnp.concatenate(
            [rows.reshape(p, n, bs), jnp.zeros((p, 1, bs), xs.dtype)], axis=1
        ).astype(jnp.float32)
        for (k, off) in reversed(rounds):
            sk = bundle.skip[k]
            # reverse of my forward receive: what I got, I now send
            e_send = recv_t[(r - jidx) % p, k] + off
            send_slot = jnp.where(e_send < 0, n, jnp.minimum(e_send, n - 1))
            msg = jnp.take_along_axis(buf, send_slot[:, None, None], axis=1)
            # drain after send (each partial flows along one tree edge)
            buf = jax.lax.scatter(
                buf, jnp.stack([jidx, send_slot], axis=-1),
                jnp.zeros((p, bs), buf.dtype),
                jax.lax.ScatterDimensionNumbers(
                    update_window_dims=(1,), inserted_window_dims=(0, 1),
                    scatter_dims_to_operand_dims=(0, 1)),
                mode="promise_in_bounds",
            )
            got = jax.lax.ppermute(msg, axis_name, _rot_perm(p, p - sk % p))
            # accumulate into the reverse of my forward send slot
            e_acc = recv_t[(r - jidx + sk) % p, k] + off
            acc_slot = jnp.where(e_acc < 0, n, jnp.minimum(e_acc, n - 1))
            buf = jax.lax.scatter_add(
                buf, jnp.stack([jidx, acc_slot], axis=-1), got[:, 0, :],
                jax.lax.ScatterDimensionNumbers(
                    update_window_dims=(1,), inserted_window_dims=(0, 1),
                    scatter_dims_to_operand_dims=(0, 1)),
                mode="promise_in_bounds",
            )
        own = jax.lax.dynamic_slice(buf, (r, 0, 0), (1, n, bs))
        out = own.reshape(-1)[:shard].astype(xs.dtype)
        return out[None]

    shard_fn = _shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name)
    )
    return shard_fn(x)


# ------------------------------------- reversed-schedule collective family
#
# The recv/send schedules are time-reversible (Träff, arXiv:2407.18004):
# replaying the broadcast rounds backwards (t -> R-1-t) with every edge
# flipped turns the round-optimal broadcast into a round-optimal
# *reduction*, and composing reduction + broadcast yields all-reduction
# in 2(n-1)+2*ceil(log2 p) rounds.  The reversed tables come from the
# same cached bundle (engine rev_recv/rev_send: the forward tables with
# roles swapped -- no second table build).


def _op_combine(op: str):
    if op in ("sum", "+"):
        return jnp.add
    if op == "max":
        return jnp.maximum
    raise ValueError(f"unsupported reduction op {op!r} (use 'sum' or 'max')")


def _op_identity(op: str, dtype) -> jnp.ndarray:
    """Scalar identity of ``op`` in ``dtype`` (drained partials hold it)."""
    if op in ("sum", "+"):
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.inexact):
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).min, dtype)


def circulant_reduce(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    op: str = "sum",
    model: CommModel = CommModel(),
):
    """Round-optimal n-block reduction to ``root`` (reversed Algorithm 1).

    ``x`` has a leading axis of size p sharded over ``axis_name`` (each
    rank owns one slice).  Returns an array of the same spec where the
    root's slice is the elementwise op-reduction of all slices and every
    other slice is zero.  Runs in n-1+ceil(log2 p) ppermute rounds: the
    reversed round for forward round (k, off) sends the partial of the
    forward-*received* block to the forward from-neighbor (rotation by
    -skip[k]) and accumulates the incoming partial into the
    forward-*sent* block.  Partials are drained after each forward
    (capture - drain - accumulate), so final-phase capped re-sends move
    an already-emptied (identity) partial and every contribution reaches
    the root exactly once.
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    bundle = get_bundle(p, root)
    if x.shape[0] != p:
        raise ValueError("x must have leading axis == axis size (one slice/rank)")
    combine = _op_combine(op)
    elems = int(np.prod(x.shape[1:]))
    n = n_blocks or max(1, optimal_num_blocks_reduce(p, elems * x.dtype.itemsize, model))
    n = min(n, max(1, elems))
    recv_t, send_t = bundle.jnp_tables()
    rounds = bundle.reversed_round_plan(n)
    ident = _op_identity(op, x.dtype)

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        flat = xs.reshape(-1)
        buf, bs, pad = _split_blocks(flat, n)
        ident_blk = jnp.full((1, bs), ident, buf.dtype)
        # Reversed roles: forward recv entries say what r forwards,
        # forward send entries say what r accumulates.
        my_fwd = recv_t[r]
        my_acc = send_t[r]
        is_root = r == root
        for (k, off) in rounds:
            sb = my_fwd[k] + off
            ab = my_acc[k] + off
            send_slot = jnp.where(sb < 0, n, jnp.minimum(sb, n - 1))
            acc_slot = jnp.where(ab < 0, n, jnp.minimum(ab, n - 1))
            out_blk = jax.lax.dynamic_slice_in_dim(buf, send_slot, 1, axis=0)
            # The root never forwards: forward rounds never send TO the
            # root, so reversed rounds never send FROM it (phase offsets
            # can lift its negative entries in capped rounds -- those were
            # the suppressed redundant re-sends).  It ships the identity
            # instead, and drains only the garbage slot.
            out_blk = jnp.where(is_root, ident_blk, out_blk)
            drain_slot = jnp.where(is_root, n, send_slot)
            # Drain after capture: the partial leaves this rank for good.
            buf = jax.lax.dynamic_update_slice_in_dim(
                buf, ident_blk, drain_slot, axis=0
            )
            got = jax.lax.ppermute(
                out_blk, axis_name, _rot_perm(p, (p - bundle.skip[k]) % p)
            )
            cur = jax.lax.dynamic_slice_in_dim(buf, acc_slot, 1, axis=0)
            buf = jax.lax.dynamic_update_slice_in_dim(
                buf, combine(cur, got), acc_slot, axis=0
            )
        out = buf[:n].reshape(-1)[: flat.shape[0]].reshape(xs.shape)
        return jnp.where(r == root, out, jnp.zeros_like(out))

    shard = _shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
    )
    return shard(x)


def circulant_allreduce(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    op: str = "sum",
    model: CommModel = CommModel(),
):
    """All-reduction in 2(n-1)+2*ceil(log2 p) ppermute rounds.

    Reduce to ``root`` on the reversed schedule, then broadcast the
    result back on the forward schedule -- both phases run on the same
    cached ``get_bundle(p, root)`` tables and the same block count n, so
    the composition is exactly twice the optimal single-collective round
    count.  ``x`` is [p, ...] sharded over ``axis_name``; every output
    slice equals the elementwise op-reduction of all input slices.
    """
    p = mesh.shape[axis_name]
    if p == 1:
        return x
    if x.shape[0] != p:
        raise ValueError("x must have leading axis == axis size (one slice/rank)")
    elems = int(np.prod(x.shape[1:]))
    n = n_blocks or max(1, optimal_num_blocks_reduce(p, elems * x.dtype.itemsize, model))
    n = min(n, max(1, elems))
    red = circulant_reduce(
        mesh, axis_name, x, n_blocks=n, root=root, op=op, model=model
    )
    return circulant_broadcast(
        mesh, axis_name, red, n_blocks=n, root=root, model=model
    )


def circulant_allbroadcast(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    model: CommModel = CommModel(),
):
    """All-broadcast: every rank's slice reaches every rank (n-1+q rounds).

    The collective-family name (arXiv:2407.18004) for the all-to-all
    broadcast; identical to :func:`circulant_allgather` -- each rank acts
    as the root of its own forward broadcast, all p interleaved on the
    same circulant graph with one packed message per round.
    """
    return circulant_allgather(mesh, axis_name, x, n_blocks=n_blocks, model=model)


# ----------------------------------------------------------- ring baseline


def ring_allgather(mesh: Mesh, axis_name: str, x: jax.Array):
    """Classic p-1 round ring allgather baseline (bandwidth-optimal,
    latency p-1 rounds vs the circulant's n-1+ceil(log2 p))."""
    p = mesh.shape[axis_name]
    if p == 1:
        return x

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        parts = [(r, xs)]
        cur = xs
        for _ in range(p - 1):
            cur = jax.lax.ppermute(cur, axis_name, _rot_perm(p, 1))
            parts.append((None, cur))
        # piece i came from rank (r - i) % p; place rows by origin
        buf = jnp.zeros((p,) + xs.shape, xs.dtype)
        cur = xs
        buf = jax.lax.dynamic_update_slice(buf, xs[None], (r,) + (0,) * xs.ndim)
        for i in range(1, p):
            cur = parts[i][1]
            src = (r - i) % p
            buf = jax.lax.dynamic_update_slice(buf, cur[None], (src,) + (0,) * xs.ndim)
        return buf.reshape((p * xs.shape[0],) + xs.shape[1:])

    shard = _shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(), check_vma=False
    )
    return shard(x)
