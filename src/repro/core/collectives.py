"""Legacy per-call entry points for the circulant collective family.

.. deprecated::
    These six ``circulant_*`` functions are compatibility shims over the
    plan/execute communicator API of :mod:`repro.core.comm` -- prefer

        comm = get_comm(mesh, axis_name, backend=..., model=...)
        plan = comm.plan(kind, payload_spec, n_blocks=..., root=..., op=...)
        out = plan(payload)       # or comm.broadcast(x, ...) etc.

    which pulls plan construction (bundle lookup, clamped per-round slot
    tables, round plan, round-step selection, jit executor) out of the
    hot path and generalizes payloads to arbitrary pytrees.  The shims
    resolve the process-cached communicator and plan on every call, so
    they share the compiled executors with first-class plan users -- no
    caller breaks, but each call pays a plan-cache lookup the plan API
    does not.

Semantics (unchanged from the original implementations): each
communication round ``Send(t^k) || Recv(f^k)`` on the circulant graph is
one ``jax.lax.ppermute`` with the static rotation ``r -> (r+skip[k]) %
p``; per-rank slot selection comes from the cached engine bundle's
clamped per-round tables; the per-round pack/exchange/unpack-or-
accumulate step runs through the pluggable
:class:`repro.core.roundstep.RoundStep` backend (``"jnp"`` default,
``"pallas"`` fused kernels).  Round counts are the paper's optima:
``n-1+ceil(log2 p)`` for the forward/reversed single collectives,
``2(n-1)+2*ceil(log2 p)`` for the composed all-reduction.  See
docs/comm.md for the migration table and docs/collectives.md for the
schedule construction.

The seed-era ``CirculantTables`` / ``build_tables`` aliases are kept but
now emit a real :class:`DeprecationWarning` pointing at
:func:`repro.core.engine.get_bundle`.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .comm import _rot_perm, get_comm
from .costmodel import DEFAULT_MODEL, CommModel
from .engine import ScheduleBundle, get_bundle
# Hierarchical (two-level) one-call entry points live in
# repro.core.hier; re-exported here so the functional collective
# surface stays one import for flat AND hierarchical call sites.
from .hier import (  # noqa: F401  (re-exports)
    hier_allgather,
    hier_allreduce,
    hier_broadcast,
    hier_reduce,
)
from .jaxcompat import shard_map as _shard_map

__all__ = [
    "circulant_broadcast",
    "circulant_allgather",
    "circulant_allgatherv",
    "circulant_allbroadcast",
    "circulant_reduce",
    "circulant_allreduce",
    "hier_broadcast",
    "hier_reduce",
    "hier_allreduce",
    "hier_allgather",
    "ring_allgather",
    "CirculantTables",
    "build_tables",
]


def CirculantTables(p: int) -> ScheduleBundle:  # noqa: N802 - legacy class name
    """Deprecated alias for :func:`repro.core.engine.get_bundle`."""
    warnings.warn(
        "CirculantTables(p) is deprecated; use repro.core.engine."
        "get_bundle(p, root=0) (same cached ScheduleBundle, rooted tables "
        "included)",
        DeprecationWarning,
        stacklevel=2,
    )
    return get_bundle(p)


def build_tables(p: int) -> ScheduleBundle:
    """Deprecated alias for :func:`repro.core.engine.get_bundle`."""
    warnings.warn(
        "build_tables(p) is deprecated; use repro.core.engine."
        "get_bundle(p, root=0) (same cached ScheduleBundle, rooted tables "
        "included)",
        DeprecationWarning,
        stacklevel=2,
    )
    return get_bundle(p)


# ------------------------------------------------------------------- shims


def circulant_broadcast(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    backend: str = "jnp",
    model: CommModel = DEFAULT_MODEL,
):
    """Round-optimal n-block broadcast of ``x[root]`` along a mesh axis.

    ``x`` has a leading axis of size p sharded over ``axis_name`` (each
    rank owns one slice; only the root's slice content matters).  Returns
    an array of the same spec where every slice equals ``x[root]``.
    Runs in n-1+ceil(log2 p) ppermute rounds (Algorithm 1) -- the
    paper's lower bound for n-block broadcast in the one-ported
    bidirectional model.  Shim over
    :meth:`repro.core.comm.CirculantComm.broadcast`.
    """
    return get_comm(mesh, axis_name, backend=backend, model=model).broadcast(
        x, n_blocks=n_blocks, root=root)


def circulant_allgather(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = DEFAULT_MODEL,
):
    """All-to-all broadcast (regular allgather) along a mesh axis.

    ``x``: global array sharded on its leading dim over ``axis_name``.
    Returns the fully replicated gathered array (same global shape,
    spec ()) in the optimal n-1+ceil(log2 p) rounds (Algorithm 2 with
    equal contributions).  Shim over
    :meth:`repro.core.comm.CirculantComm.allgather`.
    """
    return get_comm(mesh, axis_name, backend=backend, model=model).allgather(
        x, n_blocks=n_blocks)


def circulant_allgatherv(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    sizes: Sequence[int],
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = DEFAULT_MODEL,
):
    """Irregular allgather (MPI_Allgatherv analogue), Algorithm 2 proper.

    ``x``: [p, cap] sharded over ``axis_name``; rank j's contribution is
    x[j, :sizes[j]] (the rest is padding).  Sizes are static; the wire
    volume tracks sum(sizes), not p*max(sizes) (paper Figure 2's
    degenerate case).  Returns the replicated [p, cap] array with row j
    = rank j's data.  Shim over
    :meth:`repro.core.comm.CirculantComm.allgatherv`.

    Block sizes are ragged per root, so the data plane uses the
    round-step ``pack``/``unpack`` primitives per root row; with
    ``backend="pallas"`` that means 2p single-row kernel launches per
    round -- correct and tested, but prefer ``"jnp"`` for ragged sizes.
    """
    return get_comm(mesh, axis_name, backend=backend, model=model).allgatherv(
        x, sizes, n_blocks=n_blocks)


def circulant_reduce_scatter(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = DEFAULT_MODEL,
):
    """BEYOND-PAPER: round-optimal reduce-scatter by *time reversal* of the
    circulant all-to-all broadcast (allgather and reduce-scatter are dual
    collectives; reversing every round of Algorithm 2 -- negated
    rotations, send-what-you-received, accumulate-what-you-sent -- yields
    an n-1+ceil(log2 p)-round reduce-scatter on the same schedules).

    ``x``: [p, L] sharded on dim 0 over ``axis_name``; row r is rank r's
    full L-length contribution with L = p * shard.  Returns [p, shard]
    sharded the same way: row r = sum_r' x[r'] restricted to shard r.
    Shim over :meth:`repro.core.comm.CirculantComm.reduce_scatter`.
    """
    return get_comm(mesh, axis_name, backend=backend,
                    model=model).reduce_scatter(x, n_blocks=n_blocks)


def circulant_reduce(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    op: str = "sum",
    backend: str = "jnp",
    model: CommModel = DEFAULT_MODEL,
):
    """Round-optimal n-block reduction to ``root`` (reversed Algorithm 1).

    ``x`` has a leading axis of size p sharded over ``axis_name``.
    Returns an array of the same spec where the root's slice is the
    elementwise op-reduction (``"sum"`` or ``"max"``, exact by the
    capture-drain-accumulate rule) of all slices and every other slice
    is zero, in the optimal ``n-1+ceil(log2 p)`` rounds
    (arXiv:2407.18004 time reversal).  Shim over
    :meth:`repro.core.comm.CirculantComm.reduce`.
    """
    return get_comm(mesh, axis_name, backend=backend, model=model).reduce(
        x, n_blocks=n_blocks, root=root, op=op)


def circulant_allreduce(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    root: int = 0,
    op: str = "sum",
    backend: str = "jnp",
    model: CommModel = DEFAULT_MODEL,
):
    """All-reduction in the composed ``2(n-1)+2*ceil(log2 p)`` rounds.

    Reduce to ``root`` on the reversed schedule, then broadcast the
    result back on the forward schedule -- both phases on the same
    cached bundle and block count.  Every output slice equals the
    elementwise op-reduction of all input slices.  Shim over
    :meth:`repro.core.comm.CirculantComm.allreduce`.
    """
    return get_comm(mesh, axis_name, backend=backend, model=model).allreduce(
        x, n_blocks=n_blocks, root=root, op=op)


def circulant_allbroadcast(
    mesh: Mesh,
    axis_name: str,
    x: jax.Array,
    *,
    n_blocks: Optional[int] = None,
    backend: str = "jnp",
    model: CommModel = DEFAULT_MODEL,
):
    """All-broadcast: every rank's slice reaches every rank in the
    optimal ``n-1+ceil(log2 p)`` rounds.

    The collective-family name (arXiv:2407.18004) for the all-to-all
    broadcast of Algorithm 2; identical to :func:`circulant_allgather`.
    Shim over :meth:`repro.core.comm.CirculantComm.allbroadcast`.
    """
    return get_comm(mesh, axis_name, backend=backend,
                    model=model).allbroadcast(x, n_blocks=n_blocks)


# ----------------------------------------------------------- ring baseline


def ring_allgather(mesh: Mesh, axis_name: str, x: jax.Array):
    """Classic p-1 round ring allgather baseline (bandwidth-optimal,
    latency p-1 rounds vs the circulant's n-1+ceil(log2 p))."""
    p = mesh.shape[axis_name]
    if p == 1:
        return x

    def body(xs):
        r = jax.lax.axis_index(axis_name)
        parts = [(r, xs)]
        cur = xs
        for _ in range(p - 1):
            cur = jax.lax.ppermute(cur, axis_name, _rot_perm(p, 1))
            parts.append((None, cur))
        # piece i came from rank (r - i) % p; place rows by origin
        buf = jnp.zeros((p,) + xs.shape, xs.dtype)
        cur = xs
        buf = jax.lax.dynamic_update_slice(buf, xs[None], (r,) + (0,) * xs.ndim)
        for i in range(1, p):
            cur = parts[i][1]
            src = (r - i) % p
            buf = jax.lax.dynamic_update_slice(buf, cur[None], (src,) + (0,) * xs.ndim)
        return buf.reshape((p * xs.shape[0],) + xs.shape[1:])

    shard = _shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(), check_vma=False
    )
    return shard(x)
