"""Unified schedule engine: one cached entry point for every consumer.

Every user of the paper's broadcast schedules (the JAX collectives, the
round-based simulator, checkpoint-restore fan-out, the benchmarks) needs
the same four artifacts for a given axis size p and root:

  * the circulant-graph skips (Algorithm 3),
  * the all-rank receive table recv[p, q] (Algorithms 4-6),
  * the all-rank send table send[p, q] (Algorithms 7-9),
  * the derived round structure (n-1+q rounds, x virtual rounds, the
    per-round (k, offset) block-index folding).

The seed recomputed and re-shaped these ad hoc in each consumer, with
root relabeling done by scattered modulo arithmetic at every call site.
This module centralizes all of it behind :func:`get_bundle`:

  * **process-wide LRU caching** keyed on ``(p, root)`` -- repeated
    collective calls, elastic restores and simulator sweeps share one
    computation; ``get_bundle(p) is get_bundle(p)`` holds while cached;
  * **batched all-rank tables**: the receive table is materialized once
    into a NumPy ``[p, q]`` array (per-rank cost O(log p), Proposition 1)
    and the send table is then derived *vectorized* in one NumPy gather
    via Correctness Condition 2 / Proposition 4
    (``send[r][k] == recv[(r + skip[k]) % p][k]``) instead of running
    Algorithms 7-9 with their violation fallbacks per rank -- consumers
    (Pallas kernels, ``jnp`` constant folding, the simulator) index the
    arrays directly with no per-rank Python loops;
  * **root relabeling in one place**: bundles for ``root != 0`` are a
    row rotation of the root-0 tables (paper section 2.1 renumbers ranks
    as ``(r - root) mod p``); bundle rows are indexed by *real* rank, so
    consumers never touch the virtual numbering.

Tables are small (p * ceil(log2 p) * 2 int32 entries) and immutable
(NumPy ``writeable=False``), so sharing cached instances is safe.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, lru_cache
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .schedule import (
    ceil_log2,
    compute_skips,
    num_rounds,
    recv_schedule,
    virtual_rounds,
)

__all__ = [
    "ScheduleBundle",
    "get_bundle",
    "baseblock_table",
    "bundle_cache_clear",
    "bundle_cache_info",
    "cached_plan",
    "plan_cache_clear",
    "plan_cache_info",
    "plan_cache_keys",
    "plan_cache_limit",
]


def baseblock_table(p: int) -> np.ndarray:
    """Vectorized Algorithm 4 over all ranks: baseblock[r] for r in 0..p-1.

    One NumPy pass per skip index (q passes total, O(p log p) work with
    no per-rank Python loop).  Matches :func:`repro.core.schedule.baseblock`
    exactly: the root r=0 gets q (empty canonical skip sequence).
    """
    q = ceil_log2(p)
    skip = compute_skips(p)
    rem = np.arange(p, dtype=np.int64)
    out = np.full(p, q, dtype=np.int32)
    for k in range(q - 1, -1, -1):
        undecided = out == q
        hit = undecided & (rem == skip[k])
        out[hit] = k
        take = undecided & (rem > skip[k])
        rem[take] -= skip[k]
    return out


def _recv_table0(p: int) -> np.ndarray:
    """Root-0 receive table [p, q]: Algorithm 6 per rank (O(log p) each).

    One bulk list->array conversion beats p per-row assignments.
    """
    q = ceil_log2(p)
    skip = compute_skips(p)
    rows = [recv_schedule(p, r, skip) for r in range(p)]
    return np.asarray(rows, dtype=np.int32).reshape(p, q)


def _send_table_from_recv(recv: np.ndarray, skip: Tuple[int, ...]) -> np.ndarray:
    """Vectorized send table via Condition 2: send[r][k] = recv[(r+skip[k])%p][k].

    Proposition 4 states the O(log p) Algorithms 7-9 compute exactly this
    value, so the gather below reproduces ``send_schedule`` bit-for-bit
    while skipping the per-rank violation fallbacks entirely.
    """
    p, q = recv.shape
    ranks = np.arange(p, dtype=np.int64)[:, None]          # [p, 1]
    skips_k = np.asarray(skip[:q], dtype=np.int64)[None, :]  # [1, q]
    to = (ranks + skips_k) % p                             # [p, q] to-processors
    return np.take_along_axis(recv, to.astype(np.intp), axis=0)


@lru_cache(maxsize=128)
def _tables0(p: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cached immutable root-0 (recv, send) tables for axis size p."""
    recv = _recv_table0(p)
    send = _send_table_from_recv(recv, compute_skips(p))
    recv.setflags(write=False)
    send.setflags(write=False)
    return recv, send


# eq=False keeps object-identity __eq__/__hash__: the generated
# field-tuple versions would raise on the ndarray fields, and identity
# is the documented cache contract anyway.
@dataclass(frozen=True, eq=False)
class ScheduleBundle:
    """Everything a consumer needs to run the paper's collectives.

    ``recv`` / ``send`` are ``[p, q]`` int32 arrays whose rows are
    indexed by *real* rank -- the root relabeling ``(r - root) mod p``
    of paper section 2.1 is already folded in, so ``recv[r][k]`` is the
    block (phase-relative; negative = previous phase / nonexistent) that
    real rank ``r`` receives in round ``k`` of each q-round phase.
    """

    p: int
    root: int
    q: int
    skips: Tuple[int, ...]
    recv: np.ndarray
    send: np.ndarray

    # ``skip`` is the name the paper (and the seed's CirculantTables)
    # used; keep it as an alias so call sites read like the pseudocode.
    @property
    def skip(self) -> Tuple[int, ...]:
        return self.skips

    # ------------------------------------------------------ round structure

    def rounds(self, n: int) -> int:
        """Optimal round count for an n-block operation: n-1+q (0 if p=1)."""
        return num_rounds(self.p, n)

    def virtual_rounds(self, n: int) -> int:
        """x: initial virtual rounds so n-1+q+x is a multiple of q."""
        return virtual_rounds(self.p, n)

    # Seed-compat alias (CirculantTables.x).
    def x(self, n: int) -> int:
        return self.virtual_rounds(n)

    def round_plan(self, n: int) -> List[Tuple[int, int]]:
        """Static per-round (k, offset) pairs for an n-block operation.

        Round i uses schedule column k = i % q with the phase offset
        folded in: the effective block index is ``sched[r][k] + offset``
        (off_i = q*((i-k)//q) - x; the two adjustment loops at the top of
        Algorithm 1, precomputed per round).
        """
        q, x = self.q, self.virtual_rounds(n)
        out = []
        for i in range(x, n + q - 1 + x):
            k = i % q
            out.append((k, q * ((i - k) // q) - x))
        return out

    def per_round_tables(self, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Forward per-round tables: (recv_blocks, send_blocks, ks).

        ``recv_blocks[t, r]`` / ``send_blocks[t, r]``: effective block
        index real rank r receives / sends in forward round t (the phase
        offset of :meth:`round_plan` folded in); ``ks[t]``: the skip
        column of round t (rank r sends to ``(r + skip[ks[t]]) % p``).
        Negative entries mean "idle this round"; entries > n-1 are capped
        to n-1 by consumers (final-phase re-sends).

        Derived *vectorized* from the cached tables -- one column gather
        ``tab[:, ks].T`` plus the per-round offset broadcast.  This is
        the data-plane contract: a round-step backend
        (:mod:`repro.core.roundstep`) turns row t of these tables into
        one pack/exchange/unpack step, with the whole [R, p] array
        scalar-prefetchable by the Pallas kernels.
        """
        plan = self.round_plan(n)
        ks = np.asarray([k for k, _ in plan], dtype=np.int64)
        offs = np.asarray([off for _, off in plan], dtype=np.int64)
        recv_blocks = self.recv[:, ks].T.astype(np.int64) + offs[:, None]
        send_blocks = self.send[:, ks].T.astype(np.int64) + offs[:, None]
        return recv_blocks, send_blocks, ks

    # ------------------------------------------------ reversed (reduction) side
    #
    # The recv/send schedules are time-reversible (Träff, arXiv:2407.18004):
    # running the broadcast backwards -- reduction round t replays forward
    # round R-1-t with every edge's direction flipped -- turns the
    # round-optimal broadcast into a round-optimal *reduction* toward the
    # root, and composing reduction + broadcast gives all-reduction in
    # 2(n-1) + 2q rounds on the same circulant graph.  Under the reversal
    # the table roles swap: the block a rank *received* in forward round k
    # is the partial it *forwards* in the reversed round, and the block it
    # *sent* forward is the contribution it *accumulates* coming back.  So
    # the reversed tables are the forward tables with recv/send exchanged
    # and the communication direction negated -- served from this very
    # bundle (same cache entry, no second O(p log p) build).

    @property
    def rev_recv(self) -> np.ndarray:
        """[p, q] reversed-schedule receive table: the block real rank r
        *accumulates* in the reversed round of column k (== forward
        ``send``; the contribution flows back along the edge r sent on)."""
        return self.send

    @property
    def rev_send(self) -> np.ndarray:
        """[p, q] reversed-schedule send table: the partial real rank r
        *forwards* in the reversed round of column k (== forward ``recv``;
        negative at the root, which only accumulates)."""
        return self.recv

    @property
    def rev_neighbors_out(self) -> np.ndarray:
        """[p, q] reversed to-processors (== forward ``neighbors_in``:
        partials travel against the broadcast edges)."""
        return self.neighbors_in

    @property
    def rev_neighbors_in(self) -> np.ndarray:
        """[p, q] reversed from-processors (== forward ``neighbors_out``)."""
        return self.neighbors_out

    def reversed_round_plan(self, n: int) -> List[Tuple[int, int]]:
        """Round reindexing t -> R-1-t of :meth:`round_plan`.

        Entry t gives the (k, offset) of the forward round R-1-t; the
        reversed round t moves effective blocks ``rev_sched[r][k] + offset``
        along the *negated* skip (rank r sends to (r - skip[k]) % p).
        """
        return list(reversed(self.round_plan(n)))

    def reversed_per_round_tables(
        self, n: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-round reversed tables: (fwd_blocks, acc_blocks, ks).

        ``fwd_blocks[t, r]``: effective block index whose partial rank r
        forwards in reduction round t (to ``(r - skip[ks[t]]) % p``);
        ``acc_blocks[t, r]``: effective block index rank r accumulates
        (from ``(r + skip[ks[t]]) % p``); ``ks[t]``: the skip column of
        round t.  Negative entries mean "idle this round"; entries > n-1
        are capped to n-1 by consumers (final-phase re-sends -- harmless
        for reduction because partials are drained after each forward).

        Derived *vectorized* from the cached forward tables: one column
        gather ``tab[:, ks].T`` plus the per-round offset broadcast -- no
        per-rank recomputation (Correctness Condition 2 guarantees
        ``fwd_blocks`` of the sender equals ``acc_blocks`` of its
        receiver entry-for-entry).
        """
        plan = self.reversed_round_plan(n)
        ks = np.asarray([k for k, _ in plan], dtype=np.int64)
        offs = np.asarray([off for _, off in plan], dtype=np.int64)
        fwd = self.rev_send[:, ks].T.astype(np.int64) + offs[:, None]
        acc = self.rev_recv[:, ks].T.astype(np.int64) + offs[:, None]
        return fwd, acc, ks

    def allreduce_rounds(self, n: int) -> int:
        """Round count of the composed reduce+broadcast all-reduction:
        2(n-1) + 2*ceil(log2 p) (0 if p == 1)."""
        return 2 * self.rounds(n)

    def adjusted_tables(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """(recv, send) with the x virtual rounds folded into the entries.

        Vectorized form of the per-rank adjustment loops of Algorithm 1:
        entries of rounds k < x belong to the phase before (add q - x),
        the rest shift down by x.  Returns fresh writable copies (the
        simulator increments them in place round by round).
        """
        x = self.virtual_rounds(n)
        out = []
        for tab in (self.recv, self.send):
            adj = tab.astype(np.int64, copy=True)
            adj[:, :x] += self.q - x
            adj[:, x:] -= x
            out.append(adj)
        return out[0], out[1]

    # ------------------------------------------------------ graph structure

    @cached_property
    def neighbors_out(self) -> np.ndarray:
        """[p, q] to-processors: neighbors_out[r][k] = (r + skip[k]) % p.

        The q-regular circulant broadcast graph; identical for every
        root (relabeling is a rotation, which commutes with rotation).
        """
        ranks = np.arange(self.p, dtype=np.int64)[:, None]
        sk = np.asarray(self.skips[: self.q], dtype=np.int64)[None, :]
        arr = (ranks + sk) % self.p
        arr.setflags(write=False)
        return arr

    @cached_property
    def neighbors_in(self) -> np.ndarray:
        """[p, q] from-processors: neighbors_in[r][k] = (r - skip[k]) % p."""
        ranks = np.arange(self.p, dtype=np.int64)[:, None]
        sk = np.asarray(self.skips[: self.q], dtype=np.int64)[None, :]
        arr = (ranks - sk) % self.p
        arr.setflags(write=False)
        return arr

    @cached_property
    def baseblocks(self) -> np.ndarray:
        """[p] baseblock of each real rank's *virtual* rank (root has q)."""
        virt = (np.arange(self.p) - self.root) % self.p
        arr = baseblock_table(self.p)[virt]
        arr.setflags(write=False)
        return arr

    # ----------------------------------------------------------- accessors

    def recv_row(self, r: int) -> List[int]:
        """Receive schedule of real rank r as a plain int list."""
        return [int(v) for v in self.recv[r]]

    def send_row(self, r: int) -> List[int]:
        """Send schedule of real rank r as a plain int list."""
        return [int(v) for v in self.send[r]]

    def rev_recv_row(self, r: int) -> List[int]:
        """Reversed (reduction) receive schedule of real rank r."""
        return [int(v) for v in self.rev_recv[r]]

    def rev_send_row(self, r: int) -> List[int]:
        """Reversed (reduction) send schedule of real rank r."""
        return [int(v) for v in self.rev_send[r]]

    def jnp_tables(self):
        """(recv, send) as jnp arrays (lazy jax import so the pure-Python
        consumers never pay for it).  Deliberately NOT cached on the
        bundle: under a jit trace ``jnp.asarray`` yields trace-local
        values, and caching one would leak it across traces."""
        import jax.numpy as jnp

        return jnp.asarray(self.recv), jnp.asarray(self.send)


def get_bundle(p: int, root: int = 0) -> ScheduleBundle:
    """The process-wide cached schedule bundle for axis size p and root.

    Root relabeling happens here, once: real rank r plays virtual rank
    (r - root) mod p, so the rooted tables are a row gather of the
    cached root-0 tables.  Identity is stable while cached:
    ``get_bundle(p, root) is get_bundle(p, root)`` (argument style and
    int-like types are normalized before the cache lookup).
    """
    return _get_bundle(int(p), int(root))


@lru_cache(maxsize=256)
def _get_bundle(p: int, root: int) -> ScheduleBundle:
    q = ceil_log2(p)  # validates p >= 1 with its own message
    if not 0 <= root < p:
        raise ValueError(f"root must be in [0, p), got root={root} p={p}")
    skips = compute_skips(p)
    recv0, send0 = _tables0(p)
    if root == 0:
        recv, send = recv0, send0
    else:
        virt = (np.arange(p) - root) % p
        recv = recv0[virt]
        send = send0[virt]
        recv.setflags(write=False)
        send.setflags(write=False)
    return ScheduleBundle(p=p, root=root, q=q, skips=skips, recv=recv, send=send)


def bundle_cache_clear() -> None:
    """Drop all cached bundles and tables (benchmarks measure cold paths)."""
    _get_bundle.cache_clear()
    _tables0.cache_clear()


def bundle_cache_info():
    """(bundle, tables) functools cache statistics."""
    return _get_bundle.cache_info(), _tables0.cache_info()


# ------------------------------------------------------------ plan cache
#
# Spec-keyed plan cache alongside the bundle cache.  The bundle cache
# stores the O(p log p) schedule *tables*; this one stores everything a
# consumer derives from them for a concrete operation spec -- clamped
# per-round slot tables (repro.core.roundstep), host data-plane plans
# and device CollectivePlans (repro.core.comm).  One process-wide store
# gives the same identity contract as get_bundle: planning twice with
# the same key returns the same object, and the derived work (slot
# clamping, jit-executor construction) is paid once per process.

_plan_cache: Dict[Any, Any] = {}
_plan_stats = {"hits": 0, "misses": 0}
#: Optional LRU bound; None (the default) keeps the cache eviction-free.
_plan_limit: Optional[int] = None

_LIMIT_UNSET = object()


def cached_plan(key: Any, build: Callable[[], Any]) -> Any:
    """Return the cached plan for ``key``, building it on first use.

    ``key`` must be hashable and fully determine ``build()``'s result
    (include p, root, n, kind, backend, payload spec, ... as needed).
    Identity is stable while cached: two lookups with equal keys return
    the *same* object, so plans may be compared with ``is``.  With the
    default unbounded cache "while cached" means the process lifetime;
    under a :func:`plan_cache_limit` bound an entry may be evicted once
    it falls out of the k most recently used.
    """
    try:
        val = _plan_cache[key]
        _plan_stats["hits"] += 1
        if _plan_limit is not None:
            # LRU bookkeeping: re-insert to mark most recently used
            # (dicts preserve insertion order; unbounded mode skips this
            # so the default path stays a single dict lookup).
            del _plan_cache[key]
            _plan_cache[key] = val
        return val
    except KeyError:
        pass
    _plan_stats["misses"] += 1
    val = _plan_cache.setdefault(key, build())
    if _plan_limit is not None:
        while len(_plan_cache) > _plan_limit:
            oldest = next(iter(_plan_cache))
            del _plan_cache[oldest]
    return val


def plan_cache_limit(limit: Any = _LIMIT_UNSET) -> Optional[int]:
    """Get or set the optional LRU bound on the plan cache.

    Called with no argument, returns the current bound (``None`` =
    unbounded, the default).  ``plan_cache_limit(k)`` bounds the cache
    to the ``k`` most recently *used* entries, evicting the oldest
    immediately and on every subsequent insertion;
    ``plan_cache_limit(None)`` removes the bound (existing entries are
    kept).  The default is unbounded on purpose: it preserves the
    documented identity contract ("planning twice returns the same
    object") for the life of the process.  Bound the cache only in
    long-running loops whose payload specs churn (serving with varying
    batch shapes), where unbounded growth is a host-memory leak --
    plans evicted and re-planned are equal but not identical.
    """
    global _plan_limit
    if limit is _LIMIT_UNSET:
        return _plan_limit
    if limit is not None:
        limit = int(limit)
        if limit < 1:
            raise ValueError(f"plan_cache_limit must be >= 1 or None, "
                             f"got {limit}")
        while len(_plan_cache) > limit:
            oldest = next(iter(_plan_cache))
            del _plan_cache[oldest]
    _plan_limit = limit
    return _plan_limit


def plan_cache_clear() -> None:
    """Drop every cached plan (benchmarks measure cold planning paths)."""
    _plan_cache.clear()
    _plan_stats["hits"] = _plan_stats["misses"] = 0


def plan_cache_info() -> Dict[str, int]:
    """{'size', 'hits', 'misses'} statistics of the plan cache."""
    return {"size": len(_plan_cache), **_plan_stats}


def plan_cache_keys() -> Tuple[Any, ...]:
    """Snapshot of the current plan-cache keys.

    Every key is namespaced by its first element ("commplan",
    "hierplan", "hostplan", "hierhostplan", "slots/...", "comm",
    "hiercomm"), so mixed hierarchical and flat specs can never collide
    -- the cache-audit tests assert this invariant over the snapshot.
    The cache is eviction-free by default (plans are small and the key
    space is bounded by distinct specs), so the snapshot is also how
    tests certify that repeated planning does not grow it; an explicit
    :func:`plan_cache_limit` opts into LRU eviction.
    """
    return tuple(_plan_cache.keys())
