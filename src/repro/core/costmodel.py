"""Linear (alpha-beta) communication cost models for the paper's collectives.

Used to (a) choose the number of blocks n for a given message size as in
the paper's experiments (block size F*sqrt(m/ceil(log p)) for broadcast,
n = sqrt(m*ceil(log p))/G blocks for allgatherv), and (b) produce the
simulated Figure-1/2/3 comparisons against classic algorithms (binomial
tree, scatter-allgather, ring, recursive doubling, Bruck).

Model: sending a message of m bytes costs alpha + beta*m; all processors
may send one and receive one message per round (one-ported, fully
bidirectional); rounds are synchronous.  Costs are per the critical path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .schedule import ceil_log2

__all__ = [
    "CommModel",
    "DEFAULT_MODEL",
    "bcast_circulant_cost",
    "bcast_binomial_cost",
    "bcast_scatter_allgather_cost",
    "bcast_linear_pipeline_cost",
    "allgather_circulant_cost",
    "allgather_ring_cost",
    "allgather_bruck_cost",
    "reduce_circulant_cost",
    "reduce_binomial_cost",
    "allreduce_circulant_cost",
    "allreduce_ring_cost",
    "allreduce_recursive_doubling_cost",
    "optimal_num_blocks_bcast",
    "optimal_num_blocks_allgather",
    "optimal_num_blocks_reduce",
    "optimal_num_blocks_allreduce",
    "hier_cost",
    "optimal_hier_blocks",
]


@dataclass(frozen=True)
class CommModel:
    """alpha: per-message latency (s); beta: per-byte time (s/byte).

    Frozen (immutable) and hashable by value, so a model is a valid
    component of process-wide plan-cache keys (repro.core.comm) and the
    shared signature default below is provably never mutated.
    """

    alpha: float = 1e-6
    beta: float = 1.0 / 50e9  # ~50 GB/s link

    def msg(self, nbytes: float) -> float:
        return self.alpha + self.beta * nbytes


#: The one module-level default every collective signature shares.
#: ``CommModel`` is frozen, so exposing a single instance is safe -- and
#: it makes ``model=DEFAULT_MODEL`` calls hit the same plan-cache entry.
DEFAULT_MODEL = CommModel()


def bcast_circulant_cost(p: int, m: float, n: int, model: CommModel) -> float:
    """n-block circulant broadcast: n-1+q rounds of ceil(m/n)-byte messages."""
    if p == 1:
        return 0.0
    q = ceil_log2(p)
    return (n - 1 + q) * model.msg(math.ceil(m / n))


def bcast_binomial_cost(p: int, m: float, model: CommModel) -> float:
    """Binomial tree: q rounds of the full message."""
    if p == 1:
        return 0.0
    return ceil_log2(p) * model.msg(m)


def bcast_scatter_allgather_cost(p: int, m: float, model: CommModel) -> float:
    """Van-de-Geijn: binomial scatter + ring allgather (classic large-m)."""
    if p == 1:
        return 0.0
    q = ceil_log2(p)
    scatter = q * model.alpha + model.beta * m * (p - 1) / p
    allgather = (p - 1) * model.msg(m / p)
    return scatter + allgather


def bcast_linear_pipeline_cost(p: int, m: float, n: int, model: CommModel) -> float:
    """Linear pipeline through a chain: p-1+n-1 rounds of m/n blocks."""
    if p == 1:
        return 0.0
    return (p - 2 + n) * model.msg(math.ceil(m / n))


def allgather_circulant_cost(p: int, m: float, n: int, model: CommModel) -> float:
    """Circulant all-to-all broadcast of per-rank m/p bytes in n blocks.

    Round message: (p-1) blocks of size m/(p*n) -> n-1+q rounds.
    """
    if p == 1:
        return 0.0
    q = ceil_log2(p)
    per_round = (p - 1) * math.ceil(m / (p * n))
    return (n - 1 + q) * model.msg(per_round)


def allgather_ring_cost(p: int, m: float, model: CommModel) -> float:
    """Ring allgather: p-1 rounds of m/p bytes."""
    if p == 1:
        return 0.0
    return (p - 1) * model.msg(m / p)


def allgather_bruck_cost(p: int, m: float, model: CommModel) -> float:
    """Bruck/recursive-doubling allgather: q rounds, doubling volume."""
    if p == 1:
        return 0.0
    q = ceil_log2(p)
    total = 0.0
    have = m / p
    for _ in range(q):
        total += model.msg(min(have, m - have) if have < m else 0)
        have = min(2 * have, m)
    return total


# -------------------------- reversed-schedule family (arXiv:2407.18004)


def reduce_circulant_cost(p: int, m: float, n: int, model: CommModel) -> float:
    """n-block circulant reduction: the time-reversed broadcast, so the
    identical n-1+q rounds of ceil(m/n)-byte messages (reduction work is
    off the critical path in the alpha-beta model)."""
    return bcast_circulant_cost(p, m, n, model)


def reduce_binomial_cost(p: int, m: float, model: CommModel) -> float:
    """Binomial-tree reduction: q rounds of the full message (the
    reversed binomial broadcast)."""
    return bcast_binomial_cost(p, m, model)


def allreduce_circulant_cost(p: int, m: float, n: int, model: CommModel) -> float:
    """Circulant all-reduction: reversed reduce + forward broadcast
    pipelined on the same schedule, 2(n-1)+2q rounds of ceil(m/n)."""
    if p == 1:
        return 0.0
    q = ceil_log2(p)
    return 2 * (n - 1 + q) * model.msg(math.ceil(m / n))


def allreduce_ring_cost(p: int, m: float, model: CommModel) -> float:
    """Ring all-reduce: reduce-scatter + allgather, 2(p-1) rounds of m/p
    (bandwidth-optimal, latency-bound at 2(p-1) messages)."""
    if p == 1:
        return 0.0
    return 2 * (p - 1) * model.msg(m / p)


def allreduce_recursive_doubling_cost(p: int, m: float, model: CommModel) -> float:
    """Recursive-doubling all-reduce: q rounds of the full message."""
    if p == 1:
        return 0.0
    return ceil_log2(p) * model.msg(m)


def _clamp_blocks(n: float, cap: float) -> int:
    """Clamp an analytic block-count optimum to ``[1, floor(cap)]``.

    ``cap`` is the payload unit count blocks must not outnumber (a block
    beyond it is pure padding: it moves no payload but still costs a
    round).  Total: any float ``n``/``cap`` -- including nonfinite or
    huge optima from degenerate models -- satisfies
    ``1 <= result <= max(1, cap)``.
    """
    if not (cap > 1):                        # <=1, zero, negative, NaN
        return 1
    hi = int(cap) if math.isfinite(cap) else (1 << 31)
    if not math.isfinite(n):
        return hi if n > 0 else 1
    return max(1, min(int(round(n)), hi))


def optimal_num_blocks_bcast(p: int, m: float, model: CommModel) -> int:
    """Analytic optimum of (n-1+q)(alpha + beta*m/n) over n.

    d/dn [ (n-1+q) (alpha + beta m / n) ] = 0 gives
    n* = sqrt((q-1) * beta * m / alpha); the paper's practical rule uses
    block size F*sqrt(m/q), i.e. n ~ sqrt(m*q)/F.  We return the analytic
    optimum clamped to [1, m] (never more blocks than payload units --
    block n > m would be pad-only and waste a round).
    """
    if p == 1:
        return 1
    q = ceil_log2(p)
    if not (m > 1):
        return 1
    n = math.sqrt(max(q - 1, 1) * model.beta * m / model.alpha)
    return _clamp_blocks(n, m)


def optimal_num_blocks_reduce(p: int, m: float, model: CommModel) -> int:
    """Analytic optimum for the circulant reduction block count.

    The reversed schedule has the forward round structure, so the
    broadcast optimum n* = sqrt((q-1) beta m / alpha) carries over.
    """
    return optimal_num_blocks_bcast(p, m, model)


def optimal_num_blocks_allreduce(p: int, m: float, model: CommModel) -> int:
    """Analytic optimum for the composed all-reduction.

    Minimizing 2(n-1+q)(alpha + beta m/n) gives the same n* as a single
    phase -- the factor 2 scales the cost, not the argmin.
    """
    return optimal_num_blocks_bcast(p, m, model)


# ----------------------- two-level (hierarchical) cost, paper evaluation
#
# The paper's 36x32 evaluation cluster has an order-of-magnitude gap
# between intra-node and inter-node link costs; a flat circulant
# schedule over p = nodes*cores prices every hop with one (alpha, beta).
# The hierarchical composition (repro.core.hier) runs one circulant
# collective per level, each under its own CommModel, so the two-level
# cost is simply the sum of the per-level single-collective costs --
# and because the levels pipeline nothing into each other, the block
# counts decouple: each level's n* is the flat analytic optimum under
# its own model and message volume.

_HIER_KINDS = ("broadcast", "reduce", "allreduce", "allgather")


def hier_cost(
    kind: str,
    p_inter: int,
    p_intra: int,
    m_inter: float,
    m_intra: float,
    n_inter: int,
    n_intra: int,
    inter_model: CommModel = DEFAULT_MODEL,
    intra_model: CommModel = DEFAULT_MODEL,
) -> float:
    """Two-level cost of a hierarchical circulant collective.

    ``m_inter`` / ``m_intra`` are the bytes each level moves (they can
    differ: a hierarchical allgather's intra level only moves the node's
    share).  Broadcast/reduce compose one phase per level; allreduce
    composes both (reversed reduce + forward broadcast at each level);
    allgather composes the two all-to-all broadcast phases.
    """
    if kind not in _HIER_KINDS:
        raise ValueError(f"unknown hier kind {kind!r} (use one of {_HIER_KINDS})")
    if kind == "allgather":
        inter = allgather_circulant_cost(p_inter, m_inter, n_inter, inter_model)
        intra = allgather_circulant_cost(p_intra, m_intra, n_intra, intra_model)
    else:
        inter = bcast_circulant_cost(p_inter, m_inter, n_inter, inter_model)
        intra = bcast_circulant_cost(p_intra, m_intra, n_intra, intra_model)
    scale = 2.0 if kind == "allreduce" else 1.0
    return scale * (inter + intra)


def optimal_hier_blocks(
    p_inter: int,
    p_intra: int,
    m_inter: float,
    m_intra: float,
    inter_model: CommModel = DEFAULT_MODEL,
    intra_model: CommModel = DEFAULT_MODEL,
    kind: str = "broadcast",
) -> "tuple[int, int]":
    """Per-level optimal block counts ``(n_inter, n_intra)``.

    The two-level cost is separable (no cross-level pipelining), so each
    level takes its flat analytic optimum under its own model: the
    broadcast/reduce/allreduce argmin ``sqrt((q-1) beta m / alpha)`` or
    the allgather variant -- evaluated with the level's own (p, m).
    """
    if kind not in _HIER_KINDS:
        raise ValueError(f"unknown hier kind {kind!r} (use one of {_HIER_KINDS})")
    if kind == "allgather":
        n_inter = optimal_num_blocks_allgather(p_inter, m_inter, inter_model)
        n_intra = optimal_num_blocks_allgather(p_intra, m_intra, intra_model)
    else:
        n_inter = optimal_num_blocks_bcast(p_inter, m_inter, inter_model)
        n_intra = optimal_num_blocks_bcast(p_intra, m_intra, intra_model)
    # Per-level clamp, restated here so the composed result upholds the
    # n <= max(1, m) invariant even if a level optimizer is swapped out.
    return (_clamp_blocks(n_inter, m_inter), _clamp_blocks(n_intra, m_intra))


def optimal_num_blocks_allgather(p: int, m: float, model: CommModel) -> int:
    """Analytic optimum for the circulant allgather block count."""
    if p == 1:
        return 1
    q = ceil_log2(p)
    mb = m * (p - 1) / p  # bytes moved per full sweep
    if not (mb > 1):
        return 1
    n = math.sqrt(max(q - 1, 1) * model.beta * mb / model.alpha)
    return _clamp_blocks(n, m / p)  # blocks split the per-rank share
