"""Communicator front-end: plan once, execute many.

The paper's headline split -- an O(log p) one-time schedule
*computation* fully decoupled from the n-1+ceil(log2 p) *execution*
rounds -- deserves an API with the same shape.  This module provides it,
following the communicator/plan separation MPI-style libraries use for
exactly this collective family (Träff, arXiv:2407.18004):

  * :class:`CirculantComm` binds the static context (mesh, axis,
    round-step backend, cost model) once;
  * ``comm.plan(kind, payload_spec, ...)`` precomputes **everything**
    host-side -- the cached schedule bundle, the clamped per-round slot
    tables, the per-round ppermute rotations, the round-step backend
    handle, and the jit-compiled executor -- into an immutable
    :class:`CollectivePlan`;
  * ``plan(payload)`` runs only the traced rounds: no schedule or
    slot-table work happens per call, just a payload-spec check and the
    jit dispatch;
  * ``comm.broadcast(...)`` / ``allgather`` / ``allgatherv`` /
    ``reduce_scatter`` / ``reduce`` / ``allreduce`` / ``allbroadcast``
    are thin plan-cache lookups, so casual call sites get plan reuse
    for free.  The legacy ``circulant_*`` functions in
    :mod:`repro.core.collectives` are shims over these.

Payloads are arbitrary **pytrees**: the plan flattens the tree, splits
every leaf into the same number of blocks n (per-leaf block size
``ceil(leaf_elems / n)``, so ragged leaves just pad their last block),
and runs **one shared schedule** for all leaves -- each communication
round is one ``ppermute`` per leaf on the same rotation, so the round
count stays the single-collective optimum regardless of tree size, and
leaves keep their dtypes (no flatten-to-float32 detour).

Plans are stored in the engine's process-wide spec-keyed plan cache
(:func:`repro.core.engine.cached_plan`), keyed on (mesh, axis, backend,
model, kind, payload spec, resolved block count, root, op): planning
the same collective twice returns the *same* object -- including
``n_blocks=None`` vs an explicit ``n_blocks`` equal to the cost-model
optimum -- and the first execution's XLA compilation is shared by every
later call with the same spec.

The module also hosts the :class:`HostDataPlan` certification path: the
single-process executions of the full data plane (kernel rows standing
in for the p ranks, ``jnp.roll`` as the network exchange) that
:mod:`repro.core.simulator` asserts bit-exact against its
message-passing reference -- routed through the same plan cache, so
certification sweeps reuse slot tables and step handles too.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .costmodel import (
    DEFAULT_MODEL,
    CommModel,
    optimal_num_blocks_allgather,
    optimal_num_blocks_bcast,
    optimal_num_blocks_reduce,
)
from .engine import ScheduleBundle, cached_plan, get_bundle
from .jaxcompat import shard_map as _shard_map
from .roundstep import (
    BACKENDS,
    PhaseStatic,
    allgather_phase_static,
    broadcast_phase_static,
    broadcast_slot_plan,
    get_round_step,
    reduce_phase_static,
    reduce_slot_plan,
    scatter_phase_static,
    scatter_slot_plan,
)

__all__ = [
    "KINDS",
    "PayloadSpec",
    "payload_spec",
    "validate_payload",
    "CollectivePlan",
    "CirculantComm",
    "get_comm",
    "HostDataPlan",
    "host_plan",
]

#: Collective kinds a plan can be built for.  ``"allbroadcast"`` is the
#: family name (arXiv:2407.18004) for the all-to-all broadcast and
#: canonicalizes to ``"allgather"`` -- both resolve to the same plan.
KINDS = (
    "broadcast",
    "allgather",
    "allgatherv",
    "reduce_scatter",
    "reduce",
    "allreduce",
    "allbroadcast",
    "quantized_allreduce",
)

_CANONICAL_KIND = {"allbroadcast": "allgather"}


# ------------------------------------------------------------- payload spec


@dataclass(frozen=True)
class PayloadSpec:
    """Hashable shape/dtype signature of a pytree payload.

    ``treedef`` is the jax tree structure; ``leaves`` is a tuple of
    ``(shape, dtype)`` per leaf in flatten order.  Two payloads with
    equal specs share one plan (and one compiled executor).
    """

    treedef: Any
    leaves: Tuple[Tuple[Tuple[int, ...], np.dtype], ...]

    @property
    def num_leaves(self) -> int:
        return len(self.leaves)

    def describe(self) -> str:
        body = ", ".join(f"{s}:{np.dtype(d).name}" for s, d in self.leaves)
        return f"{self.treedef} [{body}]"


def payload_spec(payload: Any) -> PayloadSpec:
    """The :class:`PayloadSpec` of a payload pytree.

    Leaves may be jax/NumPy arrays or ``jax.ShapeDtypeStruct``s (so
    specs can be built without materializing data).  Passing an existing
    spec returns it unchanged.
    """
    if isinstance(payload, PayloadSpec):
        return payload
    leaves, treedef = jax.tree.flatten(payload)
    entries = []
    for leaf in leaves:
        if not hasattr(leaf, "shape") or not hasattr(leaf, "dtype"):
            leaf = np.asarray(leaf)
        entries.append((tuple(int(s) for s in leaf.shape), np.dtype(leaf.dtype)))
    return PayloadSpec(treedef=treedef, leaves=tuple(entries))


# ------------------------------------------------------------ small helpers


def validate_payload(spec: PayloadSpec, payload: Any) -> None:
    """Assert ``payload`` matches ``spec`` (tree structure, per-leaf
    shape and dtype) with a precise diagnostic.  Shared by every plan
    front-end (:class:`CollectivePlan` here, ``HierPlan`` in
    :mod:`repro.core.hier`), so the validation contract cannot diverge.
    """
    leaves, treedef = jax.tree.flatten(payload)
    if treedef != spec.treedef:
        raise ValueError(
            f"payload tree {treedef} does not match the plan spec "
            f"{spec.treedef}"
        )
    for i, (leaf, (shape, dtype)) in enumerate(zip(leaves, spec.leaves)):
        if not hasattr(leaf, "shape") or not hasattr(leaf, "dtype"):
            leaf = np.asarray(leaf)
        got_shape = tuple(int(s) for s in leaf.shape)
        got_dtype = np.dtype(leaf.dtype)
        if got_shape != shape or got_dtype != dtype:
            raise ValueError(
                f"payload leaf {i} is {got_shape}:{got_dtype.name}, "
                f"plan expects {shape}:{np.dtype(dtype).name}"
            )


def _rot_perm(p: int, s: int):
    """Static ppermute pairs for the rotation r -> (r + s) % p."""
    return [(r, (r + s) % p) for r in range(p)]


def _split_blocks(flat: jnp.ndarray, n: int):
    """Split a flat vector into n padded blocks + 1 garbage slot: [n+1, B]."""
    size = flat.shape[0]
    bs = -(-size // n)  # ceil
    pad = n * bs - size
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n, bs)
    garbage = jnp.zeros((1, bs), flat.dtype)
    return jnp.concatenate([blocks, garbage], axis=0), bs, pad


def _split_blocks_q(flat: jnp.ndarray, n: int, qblock: int):
    """:func:`_split_blocks` with the block size rounded up to a multiple
    of the quantization block, so schedule blocks and quantization blocks
    never straddle each other (one scale vector per schedule block)."""
    size = flat.shape[0]
    bs = -(-(-(-size // n)) // qblock) * qblock
    pad = n * bs - size
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n, bs)
    garbage = jnp.zeros((1, bs), flat.dtype)
    return jnp.concatenate([blocks, garbage], axis=0), bs, pad


def _leaf_elems(shape: Tuple[int, ...]) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _tree_executor(shard_fn: Callable, treedef: Any) -> Callable:
    """Wrap a leaves-in/leaves-out shard_map callable as payload->payload."""
    def execute(payload):
        leaves = treedef.flatten_up_to(payload)
        return jax.tree.unflatten(treedef, list(shard_fn(*leaves)))

    return execute


def _acc_dtype(dt: np.dtype):
    """Accumulation dtype for the reduce-scatter partials: sub-float32
    floats (bf16/f16) widen to float32 for stable sums; everything else
    (int32/int64/float32/float64) accumulates natively, so integer sums
    are bit-exact."""
    if jnp.issubdtype(dt, jnp.inexact) and np.dtype(dt).itemsize < 4:
        return jnp.float32
    return dt


# --------------------------------------------------------- phase bodies
#
# The per-collective round loops, factored as *phase* helpers on lists
# of per-leaf flat vectors: each takes a rank index along ONE mesh axis
# and runs that axis' rounds through the shared RoundStep backend,
# looping leaves *inside* the round loop -- every round is one ppermute
# per leaf on the same rotation, so all leaves ride one shared schedule
# (the round count is the single-collective optimum regardless of tree
# size).  The flat lowerings below wrap exactly one phase in a
# one-axis shard_map; the hierarchical layer (repro.core.hier) chains
# two of them along different axes inside one body -- ONE copy of each
# round loop serves both.


def _bcast_phase(flats, n, recv_slots, send_slots, perms, axis_name, r, step,
                 overlap=False):
    """Forward broadcast rounds along ``axis_name``; the root row holds
    the data, every row ends holding all n blocks.

    With ``overlap=True`` the round loop is double-buffered: round
    t+1's send block is packed from the PRE-update buffer -- a value
    with no data dependence on round t's ppermute result, so XLA can
    schedule the pack while the exchange is in flight -- and the staged
    step patches the single stale case ``recv[t] == send[t+1]`` with
    the received message.  Bit-exact vs the sequential loop (only the
    recv slot changes per round)."""
    recv_t = jnp.asarray(recv_slots)  # [R, p] static slot tables
    send_t = jnp.asarray(send_slots)
    R = recv_t.shape[0]
    bufs, msgs, sizes = [], [], []
    for flat in flats:
        buf, _, _ = _split_blocks(flat, n)
        buf = buf[None]                               # [1, n+1, bs]
        bufs.append(buf)
        sizes.append(flat.shape[0])
        msgs.append(step.pack(buf, send_t[0, r][None]))
    for t in range(R):
        got = [jax.lax.ppermute(m, axis_name, perms[t]) for m in msgs]
        for i in range(len(bufs)):
            if t + 1 < R:
                if overlap:
                    pre = step.pack(bufs[i], send_t[t + 1, r][None])
                    bufs[i], msgs[i] = step.shuffle_staged(
                        bufs[i], got[i], pre, recv_t[t, r][None],
                        send_t[t + 1, r][None])
                else:
                    bufs[i], msgs[i] = step.shuffle(
                        bufs[i], got[i], recv_t[t, r][None],
                        send_t[t + 1, r][None])
            else:
                bufs[i] = step.unpack(bufs[i], got[i], recv_t[t, r][None])
    return [buf[0, :n].reshape(-1)[:size]
            for buf, size in zip(bufs, sizes)]


def _reduce_phase(flats, n, fwd_slots, acc_slots, perms, axis_name, r,
                  idents, op, step, overlap=False):
    """Reversed (reduction) rounds along ``axis_name``; the root row
    ends with the op-reduction, every other row is drained to the
    identity.

    With ``overlap=True`` the captured round-t+1 forward block is packed
    from the PRE-accumulate buffer (overlapping the round-t exchange)
    and the staged step patches the coincident ``fwd == acc`` case with
    the freshly combined value -- bit-exact vs the sequential loop."""
    F = jnp.asarray(fwd_slots)  # [R, p] static slot tables (root row
    A = jnp.asarray(acc_slots)  # pinned to the identity slot n+1)
    R = F.shape[0]
    garbage = jnp.full((1,), n, jnp.int32)
    bufs, msgs, sizes = [], [], []
    for flat, ident in zip(flats, idents):
        buf, bs, _ = _split_blocks(flat, n)           # [n+1, bs]
        buf = jnp.concatenate(
            [buf, jnp.full((1, bs), ident, buf.dtype)], axis=0
        )[None]                                       # [1, n+2, bs]
        # Initial capture+drain of round 0's forwarded partial.
        buf, msg = step.acc_shuffle(
            buf, jnp.zeros((1, bs), buf.dtype), garbage, F[0, r][None], op=op)
        bufs.append(buf)
        msgs.append(msg)
        sizes.append(flat.shape[0])
    for t in range(R):
        got = [jax.lax.ppermute(m, axis_name, perms[t]) for m in msgs]
        nxt = F[t + 1, r][None] if t + 1 < R else garbage
        for i in range(len(bufs)):
            # accumulate round t's incoming partial, then capture+drain
            # round t+1's forward (each partial flows along exactly one
            # tree edge).
            if overlap:
                pre = step.pack(bufs[i], nxt)
                bufs[i], msgs[i] = step.acc_shuffle_staged(
                    bufs[i], got[i], pre, A[t, r][None], nxt, op=op)
            else:
                bufs[i], msgs[i] = step.acc_shuffle(
                    bufs[i], got[i], A[t, r][None], nxt, op=op)
    return [buf[0, :n].reshape(-1)[:size]
            for buf, size in zip(bufs, sizes)]


def _allgather_phase(flats, n, recv_slots, skips, perms, axis_name, r,
                     p, step, overlap=False):
    """All-to-all broadcast rounds along ``axis_name``: every row
    contributes its flat vector, every row ends with the [p * len]
    rank-major concatenation.  One clamped [R, p] slot table serves
    recv AND send: by Condition 2 the send slot of root row j is the
    recv slot of the shifted virtual rank, so both are gathers of the
    same table."""
    S = jnp.asarray(recv_slots)  # [R, p] static slot table
    R = S.shape[0]
    base = (r - jnp.arange(p)) % p  # virtual rank of root row j at rank r

    def send_slots_at(t):
        return S[t][(base + skips[t]) % p]

    bufs, sizes = [], []
    for flat in flats:
        # buffers[j] holds root j's blocks; only the own row is filled.
        own, _, _ = _split_blocks(flat, n)            # [n+1, bs]
        buf = jnp.zeros((p,) + own.shape, flat.dtype)
        buf = jax.lax.dynamic_update_slice(buf, own[None], (r, 0, 0))
        bufs.append(buf)
        sizes.append(flat.shape[0])
    msgs = [step.pack(buf, send_slots_at(0)) for buf in bufs]
    for t in range(R):
        got = [jax.lax.ppermute(m, axis_name, perms[t]) for m in msgs]
        for i in range(len(bufs)):
            if t + 1 < R:
                if overlap:
                    pre = step.pack(bufs[i], send_slots_at(t + 1))
                    bufs[i], msgs[i] = step.shuffle_staged(
                        bufs[i], got[i], pre, S[t][base],
                        send_slots_at(t + 1))
                else:
                    bufs[i], msgs[i] = step.shuffle(
                        bufs[i], got[i], S[t][base], send_slots_at(t + 1))
            else:
                bufs[i] = step.unpack(bufs[i], got[i], S[t][base])
    return [buf[:, :n, :].reshape(p, -1)[:, :size].reshape(-1)
            for buf, size in zip(bufs, sizes)]


def _qreduce_phase(flats, n, fwd_slots, acc_slots, perms, axis_name, r, step,
                   qblock):
    """Quantized-wire reversed (sum) rounds along ``axis_name``: the wire
    carries int8 blocks + per-qblock f32 scales; every requantization's
    error is accumulated into a per-slot error buffer on the rank that
    generated it.  Returns per-leaf ``(buf, err, bs, size)`` with buf/err
    the [1, n+2, bs] f32 buffers (root row of buf holds the lossy sum;
    err holds each rank's locally generated error in SUM units)."""
    F = jnp.asarray(fwd_slots)  # [R, p] static slot tables (root row
    A = jnp.asarray(acc_slots)  # pinned to the identity slot n+1)
    R = F.shape[0]
    garbage = jnp.full((1,), n, jnp.int32)
    bufs, errs, qmsgs, smsgs, metas = [], [], [], [], []
    for flat in flats:
        buf, bs, _ = _split_blocks_q(flat, n, qblock)  # [n+1, bs]
        nb = bs // qblock
        # slot n+1 is the sum identity (zero), matching _reduce_phase.
        buf = jnp.concatenate(
            [buf, jnp.zeros((1, bs), buf.dtype)], axis=0
        )[None]                                        # [1, n+2, bs]
        err = jnp.zeros_like(buf)
        # Initial capture+drain of round 0's forwarded partial (zero
        # message: dequant(0, 0) == 0 folds into the garbage slot).
        buf, err, qm, sm = step.qacc_shuffle(
            buf, err, jnp.zeros((1, bs), jnp.int8),
            jnp.zeros((1, nb), jnp.float32), garbage, F[0, r][None])
        bufs.append(buf)
        errs.append(err)
        qmsgs.append(qm)
        smsgs.append(sm)
        metas.append((bs, flat.shape[0]))
    for t in range(R):
        got_q = [jax.lax.ppermute(m, axis_name, perms[t]) for m in qmsgs]
        got_s = [jax.lax.ppermute(m, axis_name, perms[t]) for m in smsgs]
        nxt = F[t + 1, r][None] if t + 1 < R else garbage
        for i in range(len(bufs)):
            bufs[i], errs[i], qmsgs[i], smsgs[i] = step.qacc_shuffle(
                bufs[i], errs[i], got_q[i], got_s[i], A[t, r][None], nxt)
    return [(buf, err) + meta for buf, err, meta in zip(bufs, errs, metas)]


def _quantized_allreduce_core(flats, n, fwd_slots, acc_slots, recv_slots,
                              send_slots, red_perms, bc_perms, axis_name, r,
                              root, step, qblock):
    """int8-on-the-wire allreduce body (sum): quantized reversed reduce
    to ``root``, root-side final requantization, then the forward
    broadcast of the int8 blocks + scales, dequantized on every rank.

    Returns ``(sums, errs)``: per-leaf flat f32 lossy sums (identical on
    every rank) and per-leaf flat f32 error vectors in SUM units -- each
    rank holds only its locally generated quantization error, and

        exact_sum == lossy_sum + psum(err)

    holds bit-for-bit up to f32 accumulation order (the error-feedback
    completeness invariant; see optim/compression.py).
    """
    from repro.kernels.quant_ops import (
        dequant_blocks,
        quant_blocks,
        quant_error,
    )

    reduced = _qreduce_phase(flats, n, fwd_slots, acc_slots, red_perms,
                             axis_name, r, step, qblock)
    q_flats, s_flats, err_flats, sizes, bss = [], [], [], [], []
    for buf, err, bs, size in reduced:
        nb = bs // qblock
        data = buf[0, :n]                              # [n, bs]
        q, sc = quant_blocks(data.reshape(n * nb, qblock))
        eps = quant_error(data.reshape(n * nb, qblock), q, sc).reshape(n, bs)
        is_root = r == root
        # Non-root rows were drained by the reduce, but capped re-sends
        # can leave stale partials in slot n-1 -- zero them exactly as
        # _lower_broadcast zeroes non-root payloads.
        q_flats.append(jnp.where(is_root, q.reshape(-1),
                                 jnp.zeros((n * bs,), jnp.int8)))
        s_flats.append(jnp.where(is_root, sc.reshape(-1),
                                 jnp.zeros((n * nb,), jnp.float32)))
        # The final quantization error belongs to the root (the rank
        # that generated it); everyone else contributes zero.
        e = err[0, :n] + jnp.where(is_root, eps, jnp.zeros_like(eps))
        err_flats.append(e.reshape(-1))
        sizes.append(size)
        bss.append(bs)
    outs = _bcast_phase(q_flats + s_flats, n, recv_slots, send_slots,
                        bc_perms, axis_name, r, step)
    L = len(q_flats)
    sums, errs = [], []
    for i in range(L):
        bs, size = bss[i], sizes[i]
        nb = bs // qblock
        red = dequant_blocks(
            outs[i].reshape(n * nb, qblock),
            outs[L + i].reshape(n * nb, 1),
        ).reshape(-1)[:size]
        # Pad-lane error is identically zero (all ranks pad with exact
        # zeros), but fold the tail anyway so truncation provably never
        # drops error mass.
        e_full = err_flats[i]
        e = e_full[:size].at[size - 1].add(jnp.sum(e_full[size:]))
        sums.append(red)
        errs.append(e)
    return sums, errs


def circulant_qallreduce_body(flats, axis_name: str, p: int, *,
                              n_blocks: Optional[int] = None, root: int = 0,
                              backend: str = "jnp",
                              qblock: Optional[int] = None):
    """Run the quantized circulant allreduce inside an existing shard_map.

    ``flats``: list of flat f32 vectors (every rank passes the same
    shapes).  Returns ``(sums, errs)`` as in
    :func:`_quantized_allreduce_core`; the caller divides by ``p`` for a
    mean.  Static planning (block count, slot tables, rotations, step
    handle) is resolved once per (p, sizes, n, root, qblock, backend)
    via the process-wide plan cache -- trainers reuse one frozen plan
    per bucket spec across steps.  For a standalone collective use
    ``CirculantComm.plan("quantized_allreduce", ...)`` instead.
    """
    from repro.kernels.quant_ops import QBLOCK

    qblock = QBLOCK if qblock is None else int(qblock)
    sizes = tuple(int(f.shape[0]) for f in flats)
    if p == 1:
        return list(flats), [jnp.zeros_like(f) for f in flats]
    (n, fwd, acc, recv, send, red_perms, bc_perms) = _qsync_static(
        p, sizes, n_blocks, int(root), qblock, backend)
    step = get_round_step(backend)
    r = jax.lax.axis_index(axis_name)
    return _quantized_allreduce_core(
        flats, n, fwd, acc, recv, send, red_perms, bc_perms, axis_name, r,
        int(root), step, qblock)


def _qsync_static(p: int, sizes: Tuple[int, ...], n_blocks: Optional[int],
                  root: int, qblock: int, backend: str):
    """Cached static tables for :func:`circulant_qallreduce_body`."""
    key = ("qsync", p, sizes, n_blocks, root, qblock, backend)

    def build():
        # Wire bytes are ~1 per element (int8 + amortized scales).
        total = max(1, sum(sizes))
        n = n_blocks or max(
            1, optimal_num_blocks_reduce(p, total, DEFAULT_MODEL))
        n = min(n, max(1, -(-max(sizes) // qblock)))
        bundle = get_bundle(p, root)
        fwd, acc, ks_r = reduce_slot_plan(bundle, n)
        recv, send, ks_b = broadcast_slot_plan(bundle, n)
        red_perms = [_rot_perm(p, (p - bundle.skip[int(k)]) % p)
                     for k in ks_r]
        bc_perms = [_rot_perm(p, bundle.skip[int(k)]) for k in ks_b]
        return (n, fwd, acc, recv, send, red_perms, bc_perms)

    return cached_plan(key, build)


# ------------------------------------------------------- device lowerings
#
# One lowering per collective kind: each wraps one phase helper (or a
# bespoke loop for the irregular kinds) in a single one-axis shard_map
# and returns ``execute(payload) -> payload``.


def _lower_broadcast(mesh: Mesh, axis_name: str, bundle: ScheduleBundle,
                     n: int, root: int, backend: str,
                     spec: PayloadSpec, overlap: bool = False) -> Callable:
    p = bundle.p
    recv_slots, send_slots, ks = broadcast_slot_plan(bundle, n)
    step = get_round_step(backend)
    perms = [_rot_perm(p, bundle.skip[int(k)]) for k in ks]
    L = spec.num_leaves

    def body(*shards):
        r = jax.lax.axis_index(axis_name)
        flats, shapes = [], []
        for xs in shards:
            flat = xs.reshape(-1)
            flats.append(jnp.where(r == root, flat, jnp.zeros_like(flat)))
            shapes.append(xs.shape)
        outs = _bcast_phase(flats, n, recv_slots, send_slots, perms,
                            axis_name, r, step, overlap=overlap)
        return tuple(f.reshape(shape) for f, shape in zip(outs, shapes))

    shard_fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name),) * L,
        out_specs=(P(axis_name),) * L,
        # jax has no replication rule for pallas_call inside shard_map.
        check_vma=(backend == "jnp"),
    )

    return _tree_executor(shard_fn, spec.treedef)


def _lower_allgather(mesh: Mesh, axis_name: str, bundle: ScheduleBundle,
                     n: int, backend: str, spec: PayloadSpec,
                     overlap: bool = False) -> Callable:
    p = bundle.p
    recv_slots, _, ks = broadcast_slot_plan(bundle, n)
    step = get_round_step(backend)
    perms = [_rot_perm(p, bundle.skip[int(k)]) for k in ks]
    skips = [int(bundle.skip[int(k)]) for k in ks]
    L = spec.num_leaves

    def body(*shards):
        r = jax.lax.axis_index(axis_name)
        flats = [xs.reshape(-1) for xs in shards]
        shapes = [xs.shape for xs in shards]
        outs = _allgather_phase(flats, n, recv_slots, skips, perms,
                                axis_name, r, p, step, overlap=overlap)
        return tuple(
            f.reshape((p * shape[0],) + tuple(shape[1:]))
            for f, shape in zip(outs, shapes)
        )

    shard_fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name),) * L,
        out_specs=(P(),) * L,
        check_vma=False,  # result is replicated by construction
    )

    return _tree_executor(shard_fn, spec.treedef)


def _lower_allgatherv(mesh: Mesh, axis_name: str, bundle: ScheduleBundle,
                      n: int, backend: str, spec: PayloadSpec,
                      sizes_canon: Tuple[Tuple[int, ...], ...]) -> Callable:
    p = bundle.p
    recv_slots, _, ks = broadcast_slot_plan(bundle, n)
    step = get_round_step(backend)
    R = len(ks)
    perms = [_rot_perm(p, bundle.skip[int(k)]) for k in ks]
    skips = [int(bundle.skip[int(k)]) for k in ks]
    caps = [shape[1] for shape, _ in spec.leaves]
    # Static per-(leaf, root) block sizes: the wire volume tracks
    # sum(sizes), not p*max(sizes) (paper Figure 2's degenerate case).
    bs_all = [[max(1, -(-s // n)) for s in sizes] for sizes in sizes_canon]
    L = spec.num_leaves

    def body(*shards):
        r = jax.lax.axis_index(axis_name)
        S = jnp.asarray(recv_slots)  # [R, p] static slot table
        allbufs: List[List[jnp.ndarray]] = []
        for xs, bs_j, cap in zip(shards, bs_all, caps):
            flat = xs.reshape(-1)  # own contribution padded to cap
            bufs = []
            for j in range(p):
                pj = jnp.pad(flat[: min(cap, n * bs_j[j])],
                             (0, max(0, n * bs_j[j] - cap)))
                own = jnp.concatenate(
                    [pj[: n * bs_j[j]].reshape(n, bs_j[j]),
                     jnp.zeros((1, bs_j[j]), xs.dtype)], axis=0)
                bufs.append(jnp.where(r == j, own, jnp.zeros_like(own)))
            allbufs.append(bufs)
        for t in range(R):
            sk = skips[t]
            gots, all_slots = [], []
            for bufs, bs_j in zip(allbufs, bs_all):
                parts, slots_r = [], []
                for j in range(p):
                    ss = S[t][(r - j + sk) % p]
                    slots_r.append(S[t][(r - j) % p])
                    parts.append(step.pack(bufs[j][None], ss[None])[0])
                msg = jnp.concatenate(parts)  # [sum bs_j]
                gots.append(jax.lax.ppermute(msg, axis_name, perms[t]))
                all_slots.append(slots_r)
            for bufs, bs_j, got, slots_r in zip(allbufs, bs_all, gots,
                                                all_slots):
                o = 0
                for j in range(p):
                    piece = got[o: o + bs_j[j]][None]
                    bufs[j] = step.unpack(bufs[j][None], piece,
                                          slots_r[j][None])[0]
                    o += bs_j[j]
        outs = []
        for bufs, sizes, cap in zip(allbufs, sizes_canon, caps):
            rows = []
            for j in range(p):
                rj = bufs[j][:n].reshape(-1)[: sizes[j]]
                rows.append(jnp.pad(rj, (0, cap - sizes[j])))
            outs.append(jnp.stack(rows))
        return tuple(outs)

    shard_fn = _shard_map(
        body, mesh=mesh, in_specs=(P(axis_name),) * L,
        out_specs=(P(),) * L, check_vma=False,
    )

    return _tree_executor(shard_fn, spec.treedef)


def _lower_reduce(mesh: Mesh, axis_name: str, bundle: ScheduleBundle,
                  n: int, root: int, op: str, backend: str,
                  spec: PayloadSpec, overlap: bool = False) -> Callable:
    from repro.kernels.reduce_ops import op_identity

    p = bundle.p
    fwd_slots, acc_slots, ks = reduce_slot_plan(bundle, n)
    step = get_round_step(backend)
    perms = [_rot_perm(p, (p - bundle.skip[int(k)]) % p) for k in ks]
    idents = [op_identity(op, dt) for _, dt in spec.leaves]
    L = spec.num_leaves

    def body(*shards):
        r = jax.lax.axis_index(axis_name)
        flats = [xs.reshape(-1) for xs in shards]
        shapes = [xs.shape for xs in shards]
        outs = _reduce_phase(flats, n, fwd_slots, acc_slots, perms,
                             axis_name, r, idents, op, step,
                             overlap=overlap)
        return tuple(
            jnp.where(r == root, f, jnp.zeros_like(f)).reshape(shape)
            for f, shape in zip(outs, shapes)
        )

    shard_fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name),) * L,
        out_specs=(P(axis_name),) * L,
        check_vma=(backend == "jnp"),
    )

    return _tree_executor(shard_fn, spec.treedef)


def _lower_reduce_scatter(mesh: Mesh, axis_name: str, bundle: ScheduleBundle,
                          n: int, backend: str, spec: PayloadSpec,
                          overlap: bool = False) -> Callable:
    p = bundle.p
    fwd_slots, acc_slots, ks = scatter_slot_plan(bundle, n)
    step = get_round_step(backend)
    R = len(ks)
    perms = [_rot_perm(p, (p - bundle.skip[int(k)]) % p) for k in ks]
    shard_l = [shape[1] // p for shape, _ in spec.leaves]
    L = spec.num_leaves

    def body(*shards):
        r = jax.lax.axis_index(axis_name)
        F = jnp.asarray(fwd_slots)  # [R, p] static slot tables
        A = jnp.asarray(acc_slots)
        base = (r - jnp.arange(p)) % p
        garbage = jnp.full((p,), n, jnp.int32)
        bufs, msgs, meta = [], [], []
        for xs, shard in zip(shards, shard_l):
            rows = xs[0].reshape(p, shard)            # contribution per root
            bs = -(-shard // n)
            rows = jnp.pad(rows, ((0, 0), (0, n * bs - shard)))
            # Partials accumulate in _acc_dtype: native for ints (so the
            # sums are bit-exact) and >= float32 floats, widened to
            # float32 for bf16/f16 stability.
            buf = jnp.concatenate(
                [rows.reshape(p, n, bs), jnp.zeros((p, 1, bs), xs.dtype)],
                axis=1,
            ).astype(_acc_dtype(xs.dtype))
            # Initial capture+drain of round 0's forwarded partials.
            buf, msg = step.acc_shuffle(
                buf, jnp.zeros((p, bs), buf.dtype), garbage, F[0][base],
                op="sum")
            bufs.append(buf)
            msgs.append(msg)
            meta.append((shard, bs, xs.dtype))
        for t in range(R):
            got = [jax.lax.ppermute(m, axis_name, perms[t]) for m in msgs]
            nxt = F[t + 1][base] if t + 1 < R else garbage
            for i in range(L):
                if overlap:
                    pre = step.pack(bufs[i], nxt)
                    bufs[i], msgs[i] = step.acc_shuffle_staged(
                        bufs[i], got[i], pre, A[t][base], nxt, op="sum")
                else:
                    bufs[i], msgs[i] = step.acc_shuffle(
                        bufs[i], got[i], A[t][base], nxt, op="sum")
        outs = []
        for buf, (shard, bs, dt) in zip(bufs, meta):
            own = jax.lax.dynamic_slice(buf, (r, 0, 0), (1, n, bs))
            outs.append(own.reshape(-1)[:shard].astype(dt)[None])
        return tuple(outs)

    shard_fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name),) * L,
        out_specs=(P(axis_name),) * L,
        check_vma=(backend == "jnp"),
    )

    return _tree_executor(shard_fn, spec.treedef)


def _lower_quantized_allreduce(mesh: Mesh, axis_name: str,
                               bundle: ScheduleBundle, n: int, root: int,
                               backend: str, spec: PayloadSpec,
                               qblock: int) -> Callable:
    p = bundle.p
    fwd_slots, acc_slots, _ = reduce_slot_plan(bundle, n)
    recv_slots, send_slots, ks_b = broadcast_slot_plan(bundle, n)
    _, _, ks_r = reduce_slot_plan(bundle, n)
    step = get_round_step(backend)
    red_perms = [_rot_perm(p, (p - bundle.skip[int(k)]) % p) for k in ks_r]
    bc_perms = [_rot_perm(p, bundle.skip[int(k)]) for k in ks_b]
    L = spec.num_leaves
    treedef = spec.treedef

    def body(*shards):
        r = jax.lax.axis_index(axis_name)
        flats = [xs.reshape(-1) for xs in shards]
        shapes = [xs.shape for xs in shards]
        sums, errs = _quantized_allreduce_core(
            flats, n, fwd_slots, acc_slots, recv_slots, send_slots,
            red_perms, bc_perms, axis_name, r, root, step, qblock)
        return (tuple(f.reshape(s) for f, s in zip(sums, shapes))
                + tuple(f.reshape(s) for f, s in zip(errs, shapes)))

    shard_fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name),) * L,
        out_specs=(P(axis_name),) * (2 * L),
        # sums are replicated by construction, errs are genuinely
        # per-rank; vma checking can't express the mix (and pallas has
        # no replication rule anyway).
        check_vma=False,
    )

    def execute(payload):
        leaves = treedef.flatten_up_to(payload)
        outs = list(shard_fn(*leaves))
        return (jax.tree.unflatten(treedef, outs[:L]),
                jax.tree.unflatten(treedef, outs[L:]))

    return execute


# ------------------------------------------------------------ plan objects


@dataclass(frozen=True, eq=False)
class CollectivePlan:
    """A fully precomputed, immutable collective: call it with payloads.

    Everything static was resolved at plan time -- the cached schedule
    bundle, the clamped per-round slot tables, the per-round rotations,
    the round-step backend handle, and the jit-compiled executor.
    ``plan(payload)`` validates the payload against ``spec`` and
    dispatches the compiled rounds; there is **no** schedule or
    slot-table work per call.  Plans are cached process-wide: building
    the same plan twice returns the same object (compare with ``is``).
    """

    kind: str
    spec: PayloadSpec
    p: int
    root: int
    op: Optional[str]
    n_blocks: int
    rounds: int
    backend: str
    axis_name: str
    qblock: Optional[int] = None
    #: True when the executor runs the overlapped (double-buffered)
    #: round loop: the next round's block is packed from the pre-update
    #: buffer concurrently with the in-flight exchange, then patched by
    #: the staged step.  Bit-exact vs the sequential executor.
    overlap: bool = False
    #: Auditable per-phase schedule statics (the exact cached slot
    #: tables the executor closed over); () on the p == 1 fast path.
    #: Checked by repro.analysis.planaudit without executing a round.
    statics: Tuple[PhaseStatic, ...] = field(repr=False, default=())
    _execute: Optional[Callable] = field(repr=False, default=None)

    def __call__(self, payload: Any) -> Any:
        """Execute the collective.  ``quantized_allreduce`` plans return
        a ``(sums, errors)`` pair of payload-shaped trees; every other
        kind returns one payload-shaped tree."""
        validate_payload(self.spec, payload)
        if self._execute is None:  # p == 1 fast path: nothing moves
            return payload
        return self._execute(payload)

    def describe(self) -> str:
        """One-line human summary of the plan."""
        extra = f" op={self.op}" if self.op else ""
        if self.qblock is not None:
            extra += f" qblock={self.qblock}"
        if self.overlap:
            extra += " overlap"
        return (f"{self.kind} p={self.p} root={self.root} "
                f"n={self.n_blocks} rounds={self.rounds} "
                f"backend={self.backend}{extra} spec={self.spec.describe()}")


def _plan_statics(kind: str, bundle: ScheduleBundle, n: int,
                  axis: Optional[str] = None,
                  overlap: bool = False) -> Tuple[PhaseStatic, ...]:
    """The per-phase audit records of a flat collective, in execution
    order (the reversed reduction phase precedes the forward broadcast
    phase for the composed all-reductions)."""
    if kind == "broadcast":
        return (broadcast_phase_static(bundle, n, axis=axis,
                                       overlap=overlap),)
    if kind in ("allgather", "allgatherv"):
        return (allgather_phase_static(bundle, n, axis=axis,
                                       overlap=overlap),)
    if kind == "reduce_scatter":
        return (scatter_phase_static(bundle, n, axis=axis, overlap=overlap),)
    if kind == "reduce":
        return (reduce_phase_static(bundle, n, axis=axis, overlap=overlap),)
    # allreduce / quantized_allreduce: reversed reduce then broadcast
    return (reduce_phase_static(bundle, n, axis=axis, overlap=overlap),
            broadcast_phase_static(bundle, n, axis=axis, overlap=overlap))


# --------------------------------------------------------- n-block choice


def _resolve_broadcast(spec: PayloadSpec, p: int, n_blocks: Optional[int],
                       model: CommModel, optimizer) -> int:
    elems, total = [], 0
    for shape, dtype in spec.leaves:
        _require(len(shape) >= 1 and shape[0] == p,
                 "payload leaves must have leading axis == axis size "
                 f"(one slice/rank); got {shape} for p={p}")
        e = _leaf_elems(shape[1:])
        elems.append(e)
        total += e * np.dtype(dtype).itemsize
    n = n_blocks or max(1, optimizer(p, total, model))
    return min(n, max(1, max(elems)))


def _resolve_allgather(spec: PayloadSpec, p: int, n_blocks: Optional[int],
                       model: CommModel) -> int:
    shard_elems, total = [], 0
    for shape, dtype in spec.leaves:
        _require(len(shape) >= 1 and shape[0] % p == 0,
                 f"leading dim {shape[0] if shape else 0} not divisible by "
                 f"axis size {p}")
        e = (shape[0] // p) * _leaf_elems(shape[1:])
        shard_elems.append(e)
        total += e * np.dtype(dtype).itemsize
    n = n_blocks or max(1, optimal_num_blocks_allgather(p, total * p, model))
    return min(n, max(1, max(shard_elems)))


def _resolve_allgatherv(spec: PayloadSpec, p: int, n_blocks: Optional[int],
                        model: CommModel,
                        sizes_canon: Tuple[Tuple[int, ...], ...]) -> int:
    total = 0
    min_pos = None
    for (shape, dtype), sizes in zip(spec.leaves, sizes_canon):
        _require(len(shape) == 2 and shape[0] == p,
                 f"allgatherv leaves must be [p, cap]; got {shape} for p={p}")
        _require(len(sizes) == p, f"sizes must have length p={p}")
        for s in sizes:
            _require(0 <= s <= shape[1],
                     f"size {s} out of range for leaf capacity {shape[1]}")
            if s > 0:
                min_pos = s if min_pos is None else min(min_pos, s)
        total += sum(sizes) * np.dtype(dtype).itemsize
    n = n_blocks or max(
        1, optimal_num_blocks_allgather(p, max(total, 1), model))
    return min(n, max(1, min_pos if min_pos is not None else 1))


def _resolve_quantized(spec: PayloadSpec, p: int, n_blocks: Optional[int],
                       model: CommModel, qblock: int) -> int:
    elems = []
    total = 0
    for shape, dtype in spec.leaves:
        _require(len(shape) >= 1 and shape[0] == p,
                 "payload leaves must have leading axis == axis size "
                 f"(one slice/rank); got {shape} for p={p}")
        _require(np.dtype(dtype) == np.float32,
                 "quantized_allreduce requires float32 leaves (cast, or "
                 "use optim.compression.compressed_allreduce_tree for "
                 f"bf16/f16 gradients); got {np.dtype(dtype).name}")
        e = _leaf_elems(shape[1:])
        elems.append(e)
        total += e  # ~1 wire byte per element (int8 + amortized scales)
    n = n_blocks or max(
        1, optimal_num_blocks_reduce(p, max(total, 1), model))
    # More blocks than ceil(elems/qblock) would be pure padding.
    return min(n, max(1, -(-max(elems) // qblock)))


def _resolve_reduce_scatter(spec: PayloadSpec, p: int,
                            n_blocks: Optional[int],
                            model: CommModel) -> int:
    shards, total = [], 0
    for shape, dtype in spec.leaves:
        _require(len(shape) == 2 and shape[0] == p,
                 f"reduce_scatter leaves must be [p, L]; got {shape}")
        _require(shape[1] % p == 0,
                 f"row length {shape[1]} not divisible by p={p}")
        shards.append(shape[1] // p)
        total += shape[1] * np.dtype(dtype).itemsize
    n = n_blocks or max(1, optimal_num_blocks_allgather(p, total, model))
    return min(n, max(1, max(shards)))


def _is_sizes_leaf(x: Any) -> bool:
    """A per-rank size vector: a flat int sequence or a NumPy array."""
    if isinstance(x, np.ndarray):
        return True
    return isinstance(x, (list, tuple)) and all(
        isinstance(s, (int, np.integer)) for s in x)


def _canon_sizes(spec: PayloadSpec, sizes: Any) -> Tuple[Tuple[int, ...], ...]:
    """Normalize allgatherv sizes: one per-rank list shared by every
    leaf, or a pytree of per-rank lists matching the payload structure."""
    _require(sizes is not None, "allgatherv requires sizes")
    if _is_sizes_leaf(sizes):
        per_leaf = [sizes] * spec.num_leaves
    else:
        treedef = jax.tree.structure(sizes, is_leaf=_is_sizes_leaf)
        _require(
            treedef == spec.treedef,
            f"sizes tree {treedef} does not match payload tree "
            f"{spec.treedef} (pass one per-rank list to share it)")
        per_leaf = jax.tree.leaves(sizes, is_leaf=_is_sizes_leaf)
    return tuple(tuple(int(s) for s in leaf_sizes) for leaf_sizes in per_leaf)


# -------------------------------------------------------------- the comm


@dataclass(frozen=True)
class CirculantComm:
    """Communicator for the circulant collective family on one mesh axis.

    Binds the static context -- mesh, axis, round-step ``backend``
    (``"jnp"`` or ``"pallas"``), alpha-beta cost ``model`` -- once.
    ``plan`` precomputes a :class:`CollectivePlan`; the named collective
    methods are thin plan-cache lookups over it.  Frozen and hashable,
    so communicators themselves are valid cache keys.
    """

    mesh: Mesh
    axis_name: str
    backend: str = "jnp"
    model: CommModel = DEFAULT_MODEL

    def __post_init__(self):
        if self.axis_name not in self.mesh.shape:
            raise ValueError(
                f"axis {self.axis_name!r} not in mesh axes "
                f"{tuple(self.mesh.shape)}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown round-step backend {self.backend!r} "
                f"(use one of {BACKENDS})")

    @property
    def p(self) -> int:
        return self.mesh.shape[self.axis_name]

    # ------------------------------------------------------------- planning

    def plan(self, kind: str, spec: Any, *, n_blocks: Optional[int] = None,
             root: int = 0, op: str = "sum", sizes: Any = None,
             qblock: Optional[int] = None,
             overlap: bool = False) -> CollectivePlan:
        """Precompute a :class:`CollectivePlan` for ``kind`` and a payload
        spec (an example payload, a pytree of ``ShapeDtypeStruct``s, or a
        :class:`PayloadSpec`).  Cached process-wide: equal arguments
        return the identical plan object.

        ``kind="quantized_allreduce"`` plans the int8-on-the-wire sum
        allreduce (f32 leaves only; ``qblock`` sets the quantization
        block, default :data:`repro.kernels.quant_ops.QBLOCK`); calling
        it returns a ``(sums, errors)`` pair of payload-shaped trees.

        ``overlap=True`` plans the double-buffered executor: each
        round's pack is computed from the pre-update buffer with no data
        dependence on the in-flight exchange, so the round-to-round
        critical path shrinks to exchange -> select -> exchange
        (docs/overlap.md).  Bit-exact vs the sequential executor.
        Supported for broadcast / allgather / allbroadcast / reduce /
        allreduce / reduce_scatter; the irregular ``allgatherv`` and the
        quantized wire (whose requantization is fused into the round
        step) stay sequential.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown collective kind {kind!r} "
                             f"(use one of {KINDS})")
        kind = _CANONICAL_KIND.get(kind, kind)
        _require(not overlap or kind not in ("allgatherv",
                                             "quantized_allreduce"),
                 f"overlap= is not supported for kind {kind!r}")
        spec = payload_spec(spec)
        _require(spec.num_leaves > 0, "payload has no array leaves")
        # Arguments that don't apply to the kind are rejected (a silently
        # dropped op= or root= would return numerically wrong results
        # with no diagnostic), then normalized out of the cache key.
        rooted = kind in ("broadcast", "reduce", "allreduce",
                          "quantized_allreduce")
        reducing = kind in ("reduce", "allreduce")
        _require(rooted or int(root) == 0,
                 f"root= does not apply to kind {kind!r}")
        _require(reducing or op == "sum",
                 f"op= does not apply to kind {kind!r}"
                 + (" (reduce_scatter always sums)"
                    if kind == "reduce_scatter" else "")
                 + (" (quantized_allreduce always sums)"
                    if kind == "quantized_allreduce" else ""))
        _require(kind == "allgatherv" or sizes is None,
                 f"sizes= only applies to allgatherv, not {kind!r}")
        _require(kind == "quantized_allreduce" or qblock is None,
                 f"qblock= only applies to quantized_allreduce, "
                 f"not {kind!r}")
        root_key = int(root) if rooted else 0
        op_key = op if reducing else None
        sizes_key = _canon_sizes(spec, sizes) if kind == "allgatherv" else None
        if kind == "quantized_allreduce":
            from repro.kernels.quant_ops import QBLOCK

            qblock_key: Optional[int] = (QBLOCK if qblock is None
                                         else int(qblock))
            _require(qblock_key >= 1, f"qblock must be >= 1, got {qblock_key}")
        else:
            qblock_key = None
        # Resolve the block count up front (pure host work, also the
        # payload-shape validation) so n_blocks=None and an explicit
        # n_blocks equal to the cost-model optimum key the same entry --
        # one shard_map trace and one XLA executor, not two.
        n = self._resolve_n(kind, spec, n_blocks, sizes_key, qblock_key)
        key = ("commplan", self.mesh, self.axis_name, self.backend,
               self.model, kind, spec, n, root_key, op_key, sizes_key,
               qblock_key, bool(overlap))
        return cached_plan(key, lambda: self._build(
            kind, spec, n, root_key, op_key, sizes_key, qblock_key,
            overlap=bool(overlap)))

    def _resolve_n(self, kind: str, spec: PayloadSpec,
                   n_blocks: Optional[int], sizes_canon,
                   qblock: Optional[int] = None) -> int:
        p = self.p
        if p == 1:
            # The fast path skips payload-shape validation (matching the
            # legacy collectives); sizes lengths ARE still checked, so
            # single-device development catches a wrong-length sizes
            # list before it ships to a real mesh.
            if kind == "allgatherv":
                for sizes in sizes_canon:
                    _require(len(sizes) == p,
                             f"sizes must have length p={p}, "
                             f"got {len(sizes)}")
            return n_blocks or 1
        if kind == "broadcast":
            return _resolve_broadcast(spec, p, n_blocks, self.model,
                                      optimal_num_blocks_bcast)
        if kind == "allgather":
            return _resolve_allgather(spec, p, n_blocks, self.model)
        if kind == "allgatherv":
            return _resolve_allgatherv(spec, p, n_blocks, self.model,
                                       sizes_canon)
        if kind == "reduce_scatter":
            return _resolve_reduce_scatter(spec, p, n_blocks, self.model)
        if kind == "quantized_allreduce":
            return _resolve_quantized(spec, p, n_blocks, self.model, qblock)
        # reduce / allreduce
        return _resolve_broadcast(spec, p, n_blocks, self.model,
                                  optimal_num_blocks_reduce)

    def _build(self, kind: str, spec: PayloadSpec, n: int,
               root: int, op: Optional[str], sizes_canon,
               qblock: Optional[int] = None,
               overlap: bool = False) -> CollectivePlan:
        p = self.p
        if op is not None:
            # Validate the op name host-side, before any tracing; the
            # registry is shared with the kernels so identities agree.
            from repro.kernels.reduce_ops import op_identity

            op_identity(op, np.float32)
        if p == 1:
            # Fast path: nothing moves on a one-rank axis; the plan is
            # the identity.  quantized_allreduce still returns its
            # (sums, errors) pair -- errors identically zero.
            ex = None
            if kind == "quantized_allreduce":
                ex = lambda payload: (  # noqa: E731
                    payload, jax.tree.map(jnp.zeros_like, payload))
            return CollectivePlan(
                kind=kind, spec=spec, p=p, root=0, op=op,
                n_blocks=n, rounds=0, backend=self.backend,
                axis_name=self.axis_name, qblock=qblock, overlap=overlap,
                _execute=ex)

        bundle = get_bundle(p, root)
        mesh, axis = self.mesh, self.axis_name
        if kind == "broadcast":
            ex = _lower_broadcast(mesh, axis, bundle, n, root, self.backend,
                                  spec, overlap=overlap)
            rounds = bundle.rounds(n)
        elif kind == "allgather":
            ex = _lower_allgather(mesh, axis, bundle, n, self.backend, spec,
                                  overlap=overlap)
            rounds = bundle.rounds(n)
        elif kind == "allgatherv":
            ex = _lower_allgatherv(mesh, axis, bundle, n, self.backend, spec,
                                   sizes_canon)
            rounds = bundle.rounds(n)
        elif kind == "reduce_scatter":
            ex = _lower_reduce_scatter(mesh, axis, bundle, n, self.backend,
                                       spec, overlap=overlap)
            rounds = bundle.rounds(n)
        elif kind == "reduce":
            ex = _lower_reduce(mesh, axis, bundle, n, root, op, self.backend,
                               spec, overlap=overlap)
            rounds = bundle.rounds(n)
        elif kind == "quantized_allreduce":
            ex = _lower_quantized_allreduce(mesh, axis, bundle, n, root,
                                            self.backend, spec, qblock)
            rounds = bundle.allreduce_rounds(n)
        else:  # allreduce: reversed reduce then forward broadcast, one n
            red = _lower_reduce(mesh, axis, bundle, n, root, op, self.backend,
                                spec, overlap=overlap)
            bcast = _lower_broadcast(mesh, axis, bundle, n, root,
                                     self.backend, spec, overlap=overlap)
            ex = lambda payload: bcast(red(payload))  # noqa: E731
            rounds = bundle.allreduce_rounds(n)
        return CollectivePlan(
            kind=kind, spec=spec, p=p, root=root, op=op, n_blocks=n,
            rounds=rounds, backend=self.backend, axis_name=self.axis_name,
            qblock=qblock, overlap=overlap,
            statics=_plan_statics(kind, bundle, n, axis, overlap=overlap),
            _execute=jax.jit(ex))

    # ------------------------------------------------ collective shorthands
    #
    # Thin plan-cache lookups: spec from the payload, cached plan, call.

    def broadcast(self, x: Any, *, n_blocks: Optional[int] = None,
                  root: int = 0, overlap: bool = False) -> Any:
        """Root's slices reach every rank in ``n-1+ceil(log2 p)`` rounds."""
        return self.plan("broadcast", payload_spec(x), n_blocks=n_blocks,
                         root=root, overlap=overlap)(x)

    def allgather(self, x: Any, *, n_blocks: Optional[int] = None,
                  overlap: bool = False) -> Any:
        """All-to-all broadcast of equal contributions; replicated out."""
        return self.plan("allgather", payload_spec(x), n_blocks=n_blocks,
                         overlap=overlap)(x)

    def allgatherv(self, x: Any, sizes: Any, *,
                   n_blocks: Optional[int] = None) -> Any:
        """Irregular allgather; ``sizes`` is one per-rank list (shared by
        all leaves) or a pytree of per-rank lists matching ``x``."""
        return self.plan("allgatherv", payload_spec(x), n_blocks=n_blocks,
                         sizes=sizes)(x)

    def reduce_scatter(self, x: Any, *, n_blocks: Optional[int] = None,
                       overlap: bool = False) -> Any:
        """Time-reversed all-to-all broadcast: summed shards, scattered."""
        return self.plan("reduce_scatter", payload_spec(x),
                         n_blocks=n_blocks, overlap=overlap)(x)

    def reduce(self, x: Any, *, n_blocks: Optional[int] = None, root: int = 0,
               op: str = "sum", overlap: bool = False) -> Any:
        """Op-reduction to ``root`` on the reversed schedule."""
        return self.plan("reduce", payload_spec(x), n_blocks=n_blocks,
                         root=root, op=op, overlap=overlap)(x)

    def allreduce(self, x: Any, *, n_blocks: Optional[int] = None,
                  root: int = 0, op: str = "sum",
                  overlap: bool = False) -> Any:
        """Reduce + broadcast composition, ``2(n-1)+2*ceil(log2 p)``."""
        return self.plan("allreduce", payload_spec(x), n_blocks=n_blocks,
                         root=root, op=op, overlap=overlap)(x)

    def allbroadcast(self, x: Any, *, n_blocks: Optional[int] = None,
                     overlap: bool = False) -> Any:
        """Family name for the all-to-all broadcast (same plan)."""
        return self.plan("allbroadcast", payload_spec(x),
                         n_blocks=n_blocks, overlap=overlap)(x)

    def quantized_allreduce(self, x: Any, *,
                            n_blocks: Optional[int] = None, root: int = 0,
                            qblock: Optional[int] = None) -> Any:
        """int8-on-the-wire sum allreduce -> ``(sums, errors)`` trees
        (f32 leaves; errors are each rank's local quantization error in
        SUM units -- see docs/gradsync.md)."""
        return self.plan("quantized_allreduce", payload_spec(x),
                         n_blocks=n_blocks, root=root, qblock=qblock)(x)


def get_comm(mesh: Mesh, axis_name: str, *, backend: str = "jnp",
             model: CommModel = DEFAULT_MODEL) -> CirculantComm:
    """The process-cached :class:`CirculantComm` for this context.

    Identity is stable while cached (``get_comm(...) is get_comm(...)``
    for equal arguments), so the legacy ``circulant_*`` shims hit the
    same plan cache as first-class communicator users.
    """
    return cached_plan(
        ("comm", mesh, axis_name, backend, model),
        lambda: CirculantComm(mesh=mesh, axis_name=axis_name,
                              backend=backend, model=model))


# ----------------------------------------------------- host data plans
#
# Single-process executions of the full collectives with the R rows of
# the batched kernels standing in for the p ranks and the network
# exchange realized as a row rotation (ppermute's rotation r -> (r+s)%p
# is exactly jnp.roll along the rank axis).  The simulator runs these
# next to its message-passing reference and asserts bit-exact agreement
# -- the certification path for the Pallas backend on CPU CI.  Plans
# are cached like their device siblings: slot tables and the step
# handle are resolved once per (kind, p, n, root, op, backend).


def _as_blocks(values: np.ndarray, lead: int) -> np.ndarray:
    """Normalize payload values to [*lead_shape, n, bs] float/int blocks."""
    arr = np.asarray(values)
    return arr.reshape(arr.shape[: lead + 1] + (-1,)) if arr.ndim > lead + 1 \
        else arr.reshape(arr.shape[: lead + 1] + (1,))


def _x64():
    """Certification runs in the values' own precision: without this,
    ``jnp.asarray`` silently downcasts the reference's int64/float64
    payloads and "bit-exact" would be vacuous (or int32-overflow wrong).
    """
    from jax.experimental import enable_x64

    return enable_x64()


@jax.jit
def _jit_requant(x2d):
    """quantize + error capture under jit: one fused multiply-add per
    lane for the error, matching the round-step kernels bit-for-bit."""
    from repro.kernels.quant_ops import quant_blocks, quant_error

    q, sc = quant_blocks(x2d)
    return q, sc, quant_error(x2d, q, sc)


@dataclass(frozen=True, eq=False)
class HostDataPlan:
    """Precomputed host-side data-plane execution (the certification
    harness): slot tables, skip sequence and round-step handle resolved
    at plan time; ``run(values)`` executes only the rounds."""

    kind: str
    p: int
    n: int
    root: int
    op: Optional[str]
    backend: str
    slots: Tuple[np.ndarray, ...] = field(repr=False)
    ks: np.ndarray = field(repr=False)
    skips: Tuple[int, ...] = field(repr=False)
    step: Any = field(repr=False)
    qblock: Optional[int] = None
    overlap: bool = False

    @property
    def statics(self) -> Tuple[PhaseStatic, ...]:
        """Auditable per-phase schedule statics (see
        :mod:`repro.analysis`).  Built from the same process-cached slot
        plans ``run`` executes, so the audited arrays ARE the executed
        ones by identity."""
        return _plan_statics(self.kind, get_bundle(self.p, self.root),
                             self.n, overlap=self.overlap)

    def run(self, values: np.ndarray) -> np.ndarray:
        if self.kind == "broadcast":
            return self._run_broadcast(values)
        if self.kind == "allgather":
            return self._run_allgather(values)
        if self.kind == "quantized_allreduce":
            return self._run_quantized(values)
        return self._run_reduce(values)

    def _run_broadcast(self, values: np.ndarray) -> np.ndarray:
        """``values``: [n] (or [n, bs]) block payloads at the root ->
        final [p, n, bs] data slots of every rank."""
        p, n = self.p, self.n
        recv_slots, send_slots = self.slots
        vals = _as_blocks(values, 0)                 # [n, bs]
        buf = np.zeros((p, n + 1, vals.shape[-1]), vals.dtype)
        buf[self.root, :n] = vals
        R = len(self.ks)
        with _x64():
            buf = jnp.asarray(buf)
            msg = self.step.pack(buf, jnp.asarray(send_slots[0]))
            for t in range(R):
                got = jnp.roll(msg, self.skips[t], axis=0)
                if t + 1 < R:
                    if self.overlap:
                        pre = self.step.pack(
                            buf, jnp.asarray(send_slots[t + 1]))
                        buf, msg = self.step.shuffle_staged(
                            buf, got, pre, jnp.asarray(recv_slots[t]),
                            jnp.asarray(send_slots[t + 1]))
                    else:
                        buf, msg = self.step.shuffle(
                            buf, got, jnp.asarray(recv_slots[t]),
                            jnp.asarray(send_slots[t + 1]))
                else:
                    buf = self.step.unpack(buf, got,
                                           jnp.asarray(recv_slots[t]))
            return np.asarray(buf)[:, :n]

    def _run_allgather(self, values: np.ndarray) -> np.ndarray:
        """``values``: [p, n(, bs)] per-root payloads -> final
        [p_rank, p_root, n, bs] data slots (rank-major kernel rows)."""
        p, n = self.p, self.n
        (recv_slots,) = self.slots
        vals = _as_blocks(values, 1)                 # [p, n, bs]
        bs = vals.shape[-1]
        buf = np.zeros((p, p, n + 1, bs), vals.dtype)
        for j in range(p):
            buf[j, j, :n] = vals[j]
        base = (np.arange(p)[:, None] - np.arange(p)[None, :]) % p
        R = len(self.ks)

        def slots(t, shift):
            return jnp.asarray(recv_slots[t][(base + shift) % p].reshape(-1))

        with _x64():
            buf = jnp.asarray(buf.reshape(p * p, n + 1, bs))
            msg = self.step.pack(buf, slots(0, self.skips[0]))
            for t in range(R):
                sk = self.skips[t]
                got = jnp.roll(msg.reshape(p, p, bs), sk,
                               axis=0).reshape(p * p, bs)
                if t + 1 < R:
                    if self.overlap:
                        nxt = slots(t + 1, self.skips[t + 1])
                        pre = self.step.pack(buf, nxt)
                        buf, msg = self.step.shuffle_staged(
                            buf, got, pre, slots(t, 0), nxt)
                    else:
                        buf, msg = self.step.shuffle(
                            buf, got, slots(t, 0),
                            slots(t + 1, self.skips[t + 1]))
                else:
                    buf = self.step.unpack(buf, got, slots(t, 0))
            return np.asarray(buf).reshape(p, p, n + 1, bs)[:, :, :n]

    def _run_reduce(self, values: np.ndarray) -> np.ndarray:
        """``values``: [p, n(, bs)] per-rank contributions -> final
        [p, n, bs] data slots (row ``root`` holds the op-reduction)."""
        from repro.kernels.reduce_ops import op_identity

        p, n = self.p, self.n
        fwd_slots, acc_slots = self.slots
        vals = _as_blocks(values, 1)                 # [p, n, bs]
        bs = vals.shape[-1]
        ident = op_identity(self.op, vals.dtype)
        npbuf = np.concatenate(
            [vals, np.zeros((p, 1, bs), vals.dtype),         # garbage slot n
             np.full((p, 1, bs), ident, vals.dtype)], axis=1)  # identity n+1
        R = len(self.ks)
        with _x64():
            buf = jnp.asarray(npbuf)
            garbage = jnp.full((p,), n, jnp.int32)
            # Initial capture+drain of round 0's forwarded partials (the
            # acc part folds a zero message into the garbage slot).
            buf, msg = self.step.acc_shuffle(
                buf, jnp.zeros((p, bs), buf.dtype), garbage,
                jnp.asarray(fwd_slots[0]), op=self.op)
            for t in range(R):
                got = jnp.roll(msg, -self.skips[t], axis=0)
                nxt = (jnp.asarray(fwd_slots[t + 1]) if t + 1 < R
                       else garbage)
                if self.overlap:
                    pre = self.step.pack(buf, nxt)
                    buf, msg = self.step.acc_shuffle_staged(
                        buf, got, pre, jnp.asarray(acc_slots[t]), nxt,
                        op=self.op)
                else:
                    buf, msg = self.step.acc_shuffle(
                        buf, got, jnp.asarray(acc_slots[t]), nxt, op=self.op)
            return np.asarray(buf)[:, :n]

    def _run_quantized(self, values: np.ndarray):
        """``values``: [p, n(, bs)] per-rank f32 contributions (bs a
        multiple of qblock) -> ``(out, err)``: the [p, n, bs] lossy sums
        (every row identical) and each rank's locally generated
        quantization error, with ``values.sum(0) == out[r] + err.sum(0)``
        up to f32 accumulation order.  Runs in f32 (the wire format's
        own precision), unlike the exact kinds' x64 certification."""
        from repro.kernels.quant_ops import (
            dequant_blocks,
            quant_blocks,
            quant_error,
        )

        p, n, qb = self.p, self.n, self.qblock
        fwd_slots, acc_slots, recv_slots, send_slots = self.slots
        red_skips, bc_skips = self.skips
        vals = _as_blocks(np.asarray(values, np.float32), 1)  # [p, n, bs]
        bs = vals.shape[-1]
        if bs % qb:
            raise ValueError(f"block size {bs} not a multiple of "
                             f"qblock {qb}")
        nb = bs // qb
        npbuf = np.concatenate(
            [vals, np.zeros((p, 2, bs), np.float32)], axis=1)  # n: garbage,
        buf = jnp.asarray(npbuf)                               # n+1: identity
        err = jnp.zeros_like(buf)
        garbage = jnp.full((p,), n, jnp.int32)
        buf, err, qm, sm = self.step.qacc_shuffle(
            buf, err, jnp.zeros((p, bs), jnp.int8),
            jnp.zeros((p, nb), jnp.float32), garbage,
            jnp.asarray(fwd_slots[0]))
        R = len(red_skips)
        for t in range(R):
            gq = jnp.roll(qm, -red_skips[t], axis=0)
            gs = jnp.roll(sm, -red_skips[t], axis=0)
            nxt = (jnp.asarray(fwd_slots[t + 1]) if t + 1 < R else garbage)
            buf, err, qm, sm = self.step.qacc_shuffle(
                buf, err, gq, gs, jnp.asarray(acc_slots[t]), nxt)
        # Root-side final requantization: the wire format of the
        # broadcast phase; its error belongs to the root rank.  Jitted
        # so the error capture has the same fused multiply-add rounding
        # as the in-round captures (eager jnp materializes the f32
        # product and rounds twice).
        droot = buf[self.root, :n]                             # [n, bs]
        q, sc, eps = _jit_requant(droot.reshape(n * nb, qb))
        eps = eps.reshape(n, bs)
        err = err.at[self.root, :n].add(eps)
        qbuf = jnp.zeros((p, n + 1, bs), jnp.int8)
        qbuf = qbuf.at[self.root, :n].set(q.reshape(n, bs))
        sbuf = jnp.zeros((p, n + 1, nb), jnp.float32)
        sbuf = sbuf.at[self.root, :n].set(sc.reshape(n, nb))
        Rb = len(bc_skips)
        msgq = self.step.pack(qbuf, jnp.asarray(send_slots[0]))
        msgs_ = self.step.pack(sbuf, jnp.asarray(send_slots[0]))
        for t in range(Rb):
            gq = jnp.roll(msgq, bc_skips[t], axis=0)
            gs = jnp.roll(msgs_, bc_skips[t], axis=0)
            if t + 1 < Rb:
                qbuf, msgq = self.step.shuffle(
                    qbuf, gq, jnp.asarray(recv_slots[t]),
                    jnp.asarray(send_slots[t + 1]))
                sbuf, msgs_ = self.step.shuffle(
                    sbuf, gs, jnp.asarray(recv_slots[t]),
                    jnp.asarray(send_slots[t + 1]))
            else:
                qbuf = self.step.unpack(qbuf, gq,
                                        jnp.asarray(recv_slots[t]))
                sbuf = self.step.unpack(sbuf, gs,
                                        jnp.asarray(recv_slots[t]))
        out = dequant_blocks(
            qbuf[:, :n].reshape(p * n * nb, qb),
            sbuf[:, :n].reshape(p * n * nb, 1),
        ).reshape(p, n, bs)
        return np.asarray(out), np.asarray(err)[:, :n]


def host_plan(kind: str, p: int, n: int, *, root: int = 0, op: str = "sum",
              backend: str = "jnp", interpret: Optional[bool] = None,
              qblock: Optional[int] = None,
              overlap: bool = False) -> HostDataPlan:
    """The cached :class:`HostDataPlan` for a certification execution.

    ``kind``: ``"broadcast"``, ``"allgather"``, ``"reduce"`` or
    ``"quantized_allreduce"`` (``qblock`` applies to the latter only).
    ``overlap=True`` runs the double-buffered round loop (unsupported
    for the quantized wire), bit-exact vs the sequential one.  Equal
    arguments return the identical plan object; ``run(values)`` then
    does no schedule or slot-table work.
    """
    if kind not in ("broadcast", "allgather", "reduce",
                    "quantized_allreduce"):
        raise ValueError(f"unknown host data-plane kind {kind!r}")
    if qblock is not None and kind != "quantized_allreduce":
        raise ValueError(f"qblock= does not apply to kind {kind!r}")
    if overlap and kind == "quantized_allreduce":
        raise ValueError("overlap= is not supported for kind "
                         "'quantized_allreduce'")
    if kind == "quantized_allreduce":
        from repro.kernels.quant_ops import QBLOCK

        qblock = QBLOCK if qblock is None else int(qblock)
    root_key = int(root) if kind != "allgather" else 0
    op_key = op if kind in ("reduce", "quantized_allreduce") else None
    if kind == "quantized_allreduce" and op != "sum":
        raise ValueError("quantized_allreduce always sums")
    key = ("hostplan", kind, int(p), int(n), root_key, op_key, backend,
           interpret, qblock, bool(overlap))

    def build():
        bundle = get_bundle(p, root_key)
        if kind == "reduce":
            fwd, acc, ks = reduce_slot_plan(bundle, n)
            slots = (fwd, acc)
            skips = tuple(int(bundle.skip[int(k)]) for k in ks)
        elif kind == "quantized_allreduce":
            fwd, acc, ks = reduce_slot_plan(bundle, n)
            recv, send, ks_b = broadcast_slot_plan(bundle, n)
            slots = (fwd, acc, recv, send)
            # one skip tuple per phase (reduce rounds, broadcast rounds)
            skips = (tuple(int(bundle.skip[int(k)]) for k in ks),
                     tuple(int(bundle.skip[int(k)]) for k in ks_b))
        else:
            recv, send, ks = broadcast_slot_plan(bundle, n)
            slots = (recv, send) if kind == "broadcast" else (recv,)
            skips = tuple(int(bundle.skip[int(k)]) for k in ks)
        return HostDataPlan(
            kind=kind, p=int(p), n=int(n), root=root_key, op=op_key,
            backend=backend, slots=slots, ks=ks, skips=skips,
            step=get_round_step(backend, interpret), qblock=qblock,
            overlap=bool(overlap))

    return cached_plan(key, build)
