"""Verification of the schedule correctness conditions, forward and reversed.

The forward conditions (paper §2.1) are the unambiguous ground truth for
any schedule construction:

  1. recvblock[k]_r == sendblock[k]_{f_r^k}  (block received is the block
     sent by the from-processor),
  2. sendblock[k]_r == recvblock[k]_{t_r^k}  (equivalent formulation),
  3. over q rounds every processor receives q different blocks:
     union_k recvblock[k] == ({-1..-q} \\ {b-q}) u {b} where b is the
     processor's baseblock (for the root, b = q and all entries negative),
  4. every sent block was received in an earlier round, or is the
     baseblock from the previous phase: sendblock[k] == recvblock[j] for
     some j < k, or sendblock[k] == b - q.

The *reversed* schedules (recv/send roles swapped, directions negated,
rounds replayed t -> R-1-t) drive the reduction / all-reduction of the
follow-up paper (arXiv:2407.18004); their correctness conditions are the
mirror images, stated on the reversed tables:

  * reversed condition 3: over q rounds every non-root *forwards* q
    different partials (its baseblock plus one per foreign phase), so
    nothing is left behind when the reduction finishes;
  * reversed condition 4: every partial *accumulated* in the reversed
    round of column k is forwarded in a reversed-later round (column
    j < k of the same phase) or carried as the baseblock into the next
    reversed phase -- contributions never stall on a non-root.

``verify_schedules`` / ``verify_reversed_schedules`` check every
processor and raise AssertionError with a precise message on the first
failure; ``verify_bundle`` / ``verify_p`` run BOTH directions, so one
call certifies the whole collective family (broadcast, all-broadcast,
reduction, all-reduction).

CLI: ``PYTHONPATH=src python -m repro.core.verify [p ...]`` verifies the
given axis sizes (default: a representative sweep).
"""

from __future__ import annotations

from typing import List, Sequence

from .schedule import baseblock, ceil_log2, compute_skips

__all__ = [
    "verify_schedules",
    "verify_reversed_schedules",
    "verify_bundle",
    "verify_p",
    "check_condition_3",
    "check_condition_4",
    "check_reversed_condition_3",
    "check_reversed_condition_4",
]


def check_condition_3(recv: Sequence[int], b: int, q: int) -> bool:
    """Condition 3 for one processor with baseblock b."""
    expect = set(range(-q, 0))
    if b < q:  # non-root: b replaces b-q
        expect.discard(b - q)
        expect.add(b)
    # root (b == q): all negative, the full set {-1..-q}
    return set(recv) == expect and len(set(recv)) == q


def check_condition_4(recv: Sequence[int], send: Sequence[int], b: int, q: int) -> bool:
    """Condition 4 for one (non-root) processor with baseblock b."""
    if send and send[0] != b - q:
        return False
    for k in range(q):
        if send[k] == b - q:
            continue
        if not any(send[k] == recv[j] for j in range(k)):
            return False
    return True


def verify_schedules(
    p: int,
    recv: Sequence[Sequence[int]],
    send: Sequence[Sequence[int]],
) -> None:
    """Check all four correctness conditions for all p processors."""
    q = ceil_log2(p)
    skip = compute_skips(p)
    for r in range(p):
        b = baseblock(r, skip, q)
        # Condition 3
        assert check_condition_3(recv[r], b, q), (
            f"cond3 failed p={p} r={r}: recv={list(recv[r])} b={b}"
        )
        for k in range(q):
            t = (r + skip[k]) % p
            f = (r - skip[k] + p) % p
            # Conditions 1 & 2 (equivalent; check both directions)
            assert send[r][k] == recv[t][k], (
                f"cond2 failed p={p} r={r} k={k}: send={send[r][k]} "
                f"recv[t={t}]={recv[t][k]}"
            )
            assert recv[r][k] == send[f][k], (
                f"cond1 failed p={p} r={r} k={k}: recv={recv[r][k]} "
                f"send[f={f}]={send[f][k]}"
            )
        # Condition 4 (non-root only; the root sends blocks 0..q-1)
        if r == 0:
            assert list(send[r]) == list(range(q)), (
                f"root send schedule must be 0..q-1, got {list(send[r])}"
            )
        else:
            assert check_condition_4(recv[r], send[r], b, q), (
                f"cond4 failed p={p} r={r}: recv={list(recv[r])} "
                f"send={list(send[r])} b={b}"
            )


def check_reversed_condition_3(send_rev: Sequence[int], b: int, q: int) -> bool:
    """Reversed condition 3 for one processor with baseblock b.

    Over the q reversed rounds the processor forwards q *distinct*
    partials: its own baseblock b plus one block per foreign phase
    ({-q..-1} \\ {b-q}); the root (b == q) forwards only phase-carried
    negatives.  Stated on the reversed send table (== forward recv), so
    the set condition mirrors the forward condition 3.
    """
    expect = set(range(-q, 0))
    if b < q:  # non-root: the own baseblock replaces b-q
        expect.discard(b - q)
        expect.add(b)
    return set(send_rev) == expect and len(set(send_rev)) == q


def check_reversed_condition_4(
    recv_rev: Sequence[int], send_rev: Sequence[int], b: int, q: int
) -> bool:
    """Reversed condition 4 for one (non-root) processor with baseblock b.

    Reduction rounds replay forward rounds backwards (t -> R-1-t), so
    "forwarded at a reversed-later round" means a *smaller* forward
    column index: every partial accumulated in column k must be forwarded
    in some column j < k (recv_rev[k] == send_rev[j]), or be the
    baseblock handed to the next reversed phase (recv_rev[k] == b - q,
    forwarded as b one phase later).  The processor's very first
    accumulation (k = 0 side) must be the phase-carried baseblock.
    """
    if recv_rev and recv_rev[0] != b - q:
        return False
    for k in range(q):
        if recv_rev[k] == b - q:
            continue
        if not any(recv_rev[k] == send_rev[j] for j in range(k)):
            return False
    return True


def verify_reversed_schedules(
    p: int,
    recv_rev: Sequence[Sequence[int]],
    send_rev: Sequence[Sequence[int]],
) -> None:
    """Check the reversed correctness conditions for all p processors.

    ``recv_rev[r][k]`` is the block rank r accumulates and
    ``send_rev[r][k]`` the partial it forwards in the reversed round of
    column k; partials travel *against* the circulant edges, so rank r
    forwards to (r - skip[k]) % p and accumulates from (r + skip[k]) % p.
    """
    q = ceil_log2(p)
    skip = compute_skips(p)
    for r in range(p):
        b = baseblock(r, skip, q)
        # Reversed condition 3: everything a rank ever holds is forwarded.
        assert check_reversed_condition_3(send_rev[r], b, q), (
            f"rev-cond3 failed p={p} r={r}: send_rev={list(send_rev[r])} b={b}"
        )
        for k in range(q):
            t = (r + skip[k]) % p   # reversed from-processor of r
            f = (r - skip[k]) % p   # reversed to-processor of r
            # Reversed conditions 1 & 2: what r forwards along the flipped
            # edge is exactly what its reversed to-processor accumulates.
            assert send_rev[r][k] == recv_rev[f][k], (
                f"rev-cond2 failed p={p} r={r} k={k}: send_rev={send_rev[r][k]} "
                f"recv_rev[f={f}]={recv_rev[f][k]}"
            )
            assert recv_rev[r][k] == send_rev[t][k], (
                f"rev-cond1 failed p={p} r={r} k={k}: recv_rev={recv_rev[r][k]} "
                f"send_rev[t={t}]={send_rev[t][k]}"
            )
        # Reversed condition 4 (the root only accumulates; its recv_rev row
        # is the forward root send row 0..q-1, nothing to forward).
        if r == 0:
            assert list(recv_rev[r]) == list(range(q)), (
                f"root accumulation schedule must be 0..q-1, got {list(recv_rev[r])}"
            )
        else:
            assert check_reversed_condition_4(recv_rev[r], send_rev[r], b, q), (
                f"rev-cond4 failed p={p} r={r}: recv_rev={list(recv_rev[r])} "
                f"send_rev={list(send_rev[r])} b={b}"
            )


def verify_bundle(bundle) -> None:
    """Verify a :class:`repro.core.engine.ScheduleBundle` (any root).

    Bundle rows are indexed by real rank with the root relabeling folded
    in; the conditions are stated in virtual numbering, so un-rotate the
    rows (virtual rank v is real rank (v + root) mod p) and check both
    the forward (broadcast) and reversed (reduction) tables -- one call
    certifies the whole collective family.
    """
    p, root = bundle.p, bundle.root
    recv = [bundle.recv_row((v + root) % p) for v in range(p)]
    send = [bundle.send_row((v + root) % p) for v in range(p)]
    verify_schedules(p, recv, send)
    # The reversed tables are the forward ones with roles swapped
    # (rev_recv is send, rev_send is recv), so the row lists above serve
    # both directions -- no second O(p q) construction.
    verify_reversed_schedules(p, recv_rev=send, send_rev=recv)


def verify_p(p: int) -> None:
    """Compute schedules through the cached engine and verify the family
    (forward broadcast conditions + reversed reduction conditions)."""
    from .engine import get_bundle

    verify_bundle(get_bundle(p))


if __name__ == "__main__":  # pragma: no cover - exercised via benchmarks/run.py
    import sys

    _ps = [int(a) for a in sys.argv[1:]] or (
        list(range(1, 130)) + [255, 256, 511, 512, 1023, 1024, 8191, 65536]
    )
    for _p in _ps:
        verify_p(_p)
    print(f"verified forward+reversed schedules for {len(_ps)} values of p "
          f"(max {max(_ps)})")
