"""Verification of the four schedule correctness conditions (paper §2.1).

These conditions are the unambiguous ground truth for any schedule
construction:

  1. recvblock[k]_r == sendblock[k]_{f_r^k}  (block received is the block
     sent by the from-processor),
  2. sendblock[k]_r == recvblock[k]_{t_r^k}  (equivalent formulation),
  3. over q rounds every processor receives q different blocks:
     union_k recvblock[k] == ({-1..-q} \\ {b-q}) u {b} where b is the
     processor's baseblock (for the root, b = q and all entries negative),
  4. every sent block was received in an earlier round, or is the
     baseblock from the previous phase: sendblock[k] == recvblock[j] for
     some j < k, or sendblock[k] == b - q.

``verify_schedules`` checks all four for every processor and raises
AssertionError with a precise message on the first failure.
"""

from __future__ import annotations

from typing import List, Sequence

from .schedule import baseblock, ceil_log2, compute_skips

__all__ = [
    "verify_schedules",
    "verify_bundle",
    "verify_p",
    "check_condition_3",
    "check_condition_4",
]


def check_condition_3(recv: Sequence[int], b: int, q: int) -> bool:
    """Condition 3 for one processor with baseblock b."""
    expect = set(range(-q, 0))
    if b < q:  # non-root: b replaces b-q
        expect.discard(b - q)
        expect.add(b)
    # root (b == q): all negative, the full set {-1..-q}
    return set(recv) == expect and len(set(recv)) == q


def check_condition_4(recv: Sequence[int], send: Sequence[int], b: int, q: int) -> bool:
    """Condition 4 for one (non-root) processor with baseblock b."""
    if send and send[0] != b - q:
        return False
    for k in range(q):
        if send[k] == b - q:
            continue
        if not any(send[k] == recv[j] for j in range(k)):
            return False
    return True


def verify_schedules(
    p: int,
    recv: Sequence[Sequence[int]],
    send: Sequence[Sequence[int]],
) -> None:
    """Check all four correctness conditions for all p processors."""
    q = ceil_log2(p)
    skip = compute_skips(p)
    for r in range(p):
        b = baseblock(r, skip, q)
        # Condition 3
        assert check_condition_3(recv[r], b, q), (
            f"cond3 failed p={p} r={r}: recv={list(recv[r])} b={b}"
        )
        for k in range(q):
            t = (r + skip[k]) % p
            f = (r - skip[k] + p) % p
            # Conditions 1 & 2 (equivalent; check both directions)
            assert send[r][k] == recv[t][k], (
                f"cond2 failed p={p} r={r} k={k}: send={send[r][k]} "
                f"recv[t={t}]={recv[t][k]}"
            )
            assert recv[r][k] == send[f][k], (
                f"cond1 failed p={p} r={r} k={k}: recv={recv[r][k]} "
                f"send[f={f}]={send[f][k]}"
            )
        # Condition 4 (non-root only; the root sends blocks 0..q-1)
        if r == 0:
            assert list(send[r]) == list(range(q)), (
                f"root send schedule must be 0..q-1, got {list(send[r])}"
            )
        else:
            assert check_condition_4(recv[r], send[r], b, q), (
                f"cond4 failed p={p} r={r}: recv={list(recv[r])} "
                f"send={list(send[r])} b={b}"
            )


def verify_bundle(bundle) -> None:
    """Verify a :class:`repro.core.engine.ScheduleBundle` (any root).

    Bundle rows are indexed by real rank with the root relabeling folded
    in; the four conditions are stated in virtual numbering, so un-rotate
    the rows (virtual rank v is real rank (v + root) mod p) and check.
    """
    p, root = bundle.p, bundle.root
    recv = [bundle.recv_row((v + root) % p) for v in range(p)]
    send = [bundle.send_row((v + root) % p) for v in range(p)]
    verify_schedules(p, recv, send)


def verify_p(p: int) -> None:
    """Compute schedules through the cached engine and verify them."""
    from .engine import get_bundle

    verify_bundle(get_bundle(p))
