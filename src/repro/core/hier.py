"""Two-level hierarchical circulant collectives (the paper's 36x32 topology).

The paper evaluates its round-optimal broadcast on a 36-node x 32-core
cluster, where the intra-node and inter-node link costs differ by an
order of magnitude.  A flat circulant schedule over p = nodes*cores
prices every hop identically; the classic remedy -- and the one the
collective family of arXiv:2407.18004 composes naturally into -- is a
*hierarchical* two-level decomposition, one circulant collective per
level:

  * ``broadcast``: inter-node circulant broadcast among the node
    leaders (the ``root``'s core row), then an intra-node broadcast
    inside every node;
  * ``reduce`` (the dual): intra-node reduction to each node's leader,
    then inter-node reduction of the leader partials to the root;
  * ``allreduce``: intra-reduce -> inter-allreduce among leaders ->
    intra-broadcast fan-out, 2(n_C-1+q_C) + 2(n_N-1+q_N) rounds;
  * ``allgather``: leader gather + circulant exchange + local fan-out,
    realized as the equivalent two-phase all-to-all broadcast (the
    intra phase *is* the fused gather+fan-out) -- intra allgather of
    the core contributions, then inter allgather of the node blocks.

Each level gets its **own** artifacts from the process-wide engine
caches -- :func:`repro.core.engine.get_bundle` for the schedule tables,
the clamped slot plans of :mod:`repro.core.roundstep`, the shared
:class:`~repro.core.roundstep.RoundStep` backend handle -- and its own
block count from a per-level :class:`~repro.core.costmodel.CommModel`
(:func:`repro.core.costmodel.optimal_hier_blocks`).  The two phases run
inside ONE ``shard_map`` body over the 2D mesh: level-1 rounds are
``ppermute``\\ s along ``inter_axis``, level-2 rounds along
``intra_axis``, with a host-side re-blocking between them.  Payloads
are arbitrary pytrees with the same leaf packing as
:mod:`repro.core.comm` (per-leaf block split, one shared schedule per
tree per level).

Flat ranks are node-major: rank ``r = node * cores + core``; a payload
leaf's leading axis is the flat rank axis, sharded over
``P((inter_axis, intra_axis))``.  Degenerate meshes compose away: a
``1 x p`` mesh runs only the intra level (== the flat collective) and a
``p x 1`` mesh only the inter level.

The module also hosts the hierarchical **host data plane**
(:class:`HierHostPlan` / :func:`hier_host_plan`): single-process
executions composing the cached per-level host plans of
:mod:`repro.core.comm`, which :func:`repro.core.simulator.
simulate_hier_broadcast` (and friends) assert bit-exact against the
message-passing reference -- the certification path for both round-step
backends on CPU CI, including the full 36x32 grid.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .costmodel import DEFAULT_MODEL, CommModel, optimal_hier_blocks
from .engine import cached_plan, get_bundle
from .jaxcompat import shard_map as _shard_map
from .roundstep import (
    BACKENDS,
    PhaseStatic,
    allgather_phase_static,
    broadcast_phase_static,
    broadcast_slot_plan,
    get_round_step,
    reduce_phase_static,
    reduce_slot_plan,
)
from .schedule import num_rounds
from .comm import (
    PayloadSpec,
    _allgather_phase,
    _bcast_phase,
    _leaf_elems,
    _reduce_phase,
    _require,
    _rot_perm,
    _tree_executor,
    host_plan,
    payload_spec,
    validate_payload,
)

__all__ = [
    "HIER_KINDS",
    "hier_rounds",
    "HierPlan",
    "HierComm",
    "get_hier_comm",
    "hier_broadcast",
    "hier_reduce",
    "hier_allreduce",
    "hier_allgather",
    "HierHostPlan",
    "hier_host_plan",
]

#: Collective kinds the hierarchical layer composes.  ``"allbroadcast"``
#: is the family alias and canonicalizes onto ``"allgather"``.
HIER_KINDS = ("broadcast", "reduce", "allreduce", "allgather", "allbroadcast")

_CANONICAL_KIND = {"allbroadcast": "allgather"}


def hier_rounds(kind: str, nodes: int, cores: int,
                n_inter: int, n_intra: int) -> int:
    """Composed closed-form round count of a two-level collective.

    Each level contributes its flat optimum (``n-1+ceil(log2 p)``, 0 on
    a one-rank level); broadcast / reduce / allgather run one phase per
    level, the all-reduction runs both directions at both levels:
    ``2(n_C-1+q_C) + 2(n_N-1+q_N)``.
    """
    kind = _CANONICAL_KIND.get(kind, kind)
    if kind not in ("broadcast", "reduce", "allreduce", "allgather"):
        raise ValueError(f"unknown hier kind {kind!r} "
                         f"(use one of {HIER_KINDS})")
    per_level = num_rounds(nodes, n_inter) + num_rounds(cores, n_intra)
    return 2 * per_level if kind == "allreduce" else per_level


# -------------------------------------------------------- device lowerings
#
# The per-axis phase bodies (_bcast_phase / _reduce_phase /
# _allgather_phase) are the SAME helpers the flat lowerings in
# repro.core.comm wrap -- one copy of each round loop serves both
# layers.  Here two phases chain along different mesh axes inside one
# shard_map body, with the host-side flatten/split re-blocking seam
# between them.


def _level_plans(bundle, n, kind):
    """(slot arrays, ks) for one level from the process-wide plan cache."""
    if kind == "reduce":
        fwd, acc, ks = reduce_slot_plan(bundle, n)
        return (fwd, acc), ks
    recv, send, ks = broadcast_slot_plan(bundle, n)
    return (recv, send), ks


def _fwd_perms(bundle, ks):
    return [_rot_perm(bundle.p, bundle.skip[int(k)]) for k in ks]


def _rev_perms(bundle, ks):
    return [_rot_perm(bundle.p, (bundle.p - bundle.skip[int(k)]) % bundle.p)
            for k in ks]


def _lower_hier(mesh: Mesh, inter_axis: str, intra_axis: str, kind: str,
                bN, bC, nN: int, nC: int, rootN: int, rootC: int,
                op: Optional[str], backend: str,
                spec: PayloadSpec) -> Callable:
    """One shard_map body running the composed per-level phases.

    Level-1 rounds ppermute along ``inter_axis`` (all core rows run them
    in lockstep; only the leader row's data is meaningful), level-2
    rounds along ``intra_axis``.  Correctness Condition 4 guarantees no
    rank ever forwards a data slot it has not received, so the inactive
    rows cannot pollute the final state -- their buffers are overwritten
    (broadcast) or drained to the op identity (reduce) phase by phase.
    """
    N, C = bN.p, bC.p
    step = get_round_step(backend)
    L = spec.num_leaves

    # Per-level static artifacts, each from the spec-keyed engine cache:
    # (slots, perms, skips) per forward level, (slots, perms) reversed.
    # Forward (broadcast-direction) phases run for every kind but reduce.
    inter = intra = None
    if kind != "reduce":
        if N > 1:
            slots, ks = _level_plans(bN, nN, "broadcast")
            inter = (slots, _fwd_perms(bN, ks),
                     [int(bN.skip[int(k)]) for k in ks])
        if C > 1:
            slots, ks = _level_plans(bC, nC, "broadcast")
            intra = (slots, _fwd_perms(bC, ks),
                     [int(bC.skip[int(k)]) for k in ks])
    rinter = rintra = None
    if kind in ("reduce", "allreduce"):
        if N > 1:
            slots, ks = _level_plans(bN, nN, "reduce")
            rinter = (slots, _rev_perms(bN, ks))
        if C > 1:
            slots, ks = _level_plans(bC, nC, "reduce")
            rintra = (slots, _rev_perms(bC, ks))

    if op is not None:
        from repro.kernels.reduce_ops import op_identity

        idents = [op_identity(op, dt) for _, dt in spec.leaves]

    def body(*shards):
        node = jax.lax.axis_index(inter_axis)
        core = jax.lax.axis_index(intra_axis)
        shapes = [xs.shape for xs in shards]
        flats = [xs.reshape(-1) for xs in shards]

        if kind == "broadcast":
            is_root = (node == rootN) & (core == rootC)
            flats = [jnp.where(is_root, f, jnp.zeros_like(f)) for f in flats]
            if inter is not None:   # leaders: broadcast across nodes
                (recv, send), perms, _ = inter
                flats = _bcast_phase(flats, nN, recv, send, perms,
                                     inter_axis, node, step)
            if intra is not None:   # fan-out inside every node
                (recv, send), perms, _ = intra
                flats = _bcast_phase(flats, nC, recv, send, perms,
                                     intra_axis, core, step)
            return tuple(f.reshape(shape) for f, shape in
                         zip(flats, shapes))

        if kind == "reduce":
            if rintra is not None:  # each node reduces to its leader
                (fwd, acc), perms = rintra
                flats = _reduce_phase(flats, nC, fwd, acc, perms,
                                      intra_axis, core, idents, op, step)
            if rinter is not None:  # leaders reduce to the root
                (fwd, acc), perms = rinter
                flats = _reduce_phase(flats, nN, fwd, acc, perms,
                                      inter_axis, node, idents, op, step)
            is_root = (node == rootN) & (core == rootC)
            return tuple(
                jnp.where(is_root, f, jnp.zeros_like(f)).reshape(shape)
                for f, shape in zip(flats, shapes))

        if kind == "allreduce":
            if rintra is not None:
                (fwd, acc), perms = rintra
                flats = _reduce_phase(flats, nC, fwd, acc, perms,
                                      intra_axis, core, idents, op, step)
            if rinter is not None:
                (fwd, acc), perms = rinter
                flats = _reduce_phase(flats, nN, fwd, acc, perms,
                                      inter_axis, node, idents, op, step)
            if inter is not None:   # leaders: broadcast the result back
                (recv, send), perms, _ = inter
                flats = _bcast_phase(flats, nN, recv, send, perms,
                                     inter_axis, node, step)
            if intra is not None:
                (recv, send), perms, _ = intra
                flats = _bcast_phase(flats, nC, recv, send, perms,
                                     intra_axis, core, step)
            return tuple(f.reshape(shape) for f, shape in
                         zip(flats, shapes))

        # allgather: intra phase (fused leader-gather + fan-out), then
        # inter exchange of the node blocks -- rank-major output.
        if intra is not None:
            (recv, _), perms, skips = intra
            flats = _allgather_phase(flats, nC, recv, skips, perms,
                                     intra_axis, core, C, step)
        if inter is not None:
            (recv, _), perms, skips = inter
            flats = _allgather_phase(flats, nN, recv, skips, perms,
                                     inter_axis, node, N, step)
        return tuple(
            f.reshape((N * C * shape[0],) + tuple(shape[1:]))
            for f, shape in zip(flats, shapes))

    replicated_out = kind == "allgather"
    shard_fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P((inter_axis, intra_axis)),) * L,
        out_specs=((P(),) if replicated_out
                   else (P((inter_axis, intra_axis)),)) * L,
        # jax has no replication rule for pallas_call inside shard_map,
        # and the allgather result is replicated by construction.
        check_vma=(backend == "jnp") and not replicated_out,
    )

    return _tree_executor(shard_fn, spec.treedef)


def _hier_statics(kind: str, bN, bC, nN: int, nC: int, inter_axis: str,
                  intra_axis: str) -> Tuple[PhaseStatic, ...]:
    """Per-phase audit records of a two-level collective, in the exact
    execution order of :func:`_lower_hier` (one-rank levels compose
    away).  Each record's tables come from the same process-cached slot
    plans the lowering closed over."""
    N, C = bN.p, bC.p
    inter_b = ((broadcast_phase_static(bN, nN, axis=inter_axis),)
               if N > 1 else ())
    intra_b = ((broadcast_phase_static(bC, nC, axis=intra_axis),)
               if C > 1 else ())
    inter_r = ((reduce_phase_static(bN, nN, axis=inter_axis),)
               if N > 1 else ())
    intra_r = ((reduce_phase_static(bC, nC, axis=intra_axis),)
               if C > 1 else ())
    if kind == "broadcast":
        return inter_b + intra_b
    if kind == "reduce":
        return intra_r + inter_r
    if kind == "allreduce":
        return intra_r + inter_r + inter_b + intra_b
    # allgather: intra phase then inter exchange of the node blocks
    inter_g = ((allgather_phase_static(bN, nN, axis=inter_axis),)
               if N > 1 else ())
    intra_g = ((allgather_phase_static(bC, nC, axis=intra_axis),)
               if C > 1 else ())
    return intra_g + inter_g


# ------------------------------------------------------------ plan objects


@dataclass(frozen=True, eq=False)
class HierPlan:
    """A fully precomputed two-level collective: call it with payloads.

    Mirrors :class:`repro.core.comm.CollectivePlan`: every static
    artifact (both level bundles, both clamped slot-table sets, the
    per-round rotations, the round-step handle, the jit executor) was
    resolved at plan time; ``plan(payload)`` validates the payload and
    dispatches the compiled rounds.  Cached process-wide -- equal specs
    return the identical object.
    """

    kind: str
    spec: PayloadSpec
    nodes: int
    cores: int
    root: int
    op: Optional[str]
    n_inter: int
    n_intra: int
    rounds: int
    rounds_inter: int
    rounds_intra: int
    backend: str
    inter_axis: str
    intra_axis: str
    #: Auditable per-phase schedule statics in execution order (see
    #: repro.analysis.planaudit); () on the p == 1 fast path.
    statics: Tuple[PhaseStatic, ...] = field(repr=False, default=())
    _execute: Optional[Callable] = field(repr=False, default=None)

    @property
    def p(self) -> int:
        return self.nodes * self.cores

    def __call__(self, payload: Any) -> Any:
        validate_payload(self.spec, payload)
        if self._execute is None:  # p == 1 fast path: nothing moves
            return payload
        return self._execute(payload)

    def describe(self) -> str:
        """One-line human summary of the plan."""
        extra = f" op={self.op}" if self.op else ""
        return (f"hier-{self.kind} mesh={self.nodes}x{self.cores} "
                f"root={self.root} n=({self.n_inter},{self.n_intra}) "
                f"rounds={self.rounds} (inter {self.rounds_inter} + intra "
                f"{self.rounds_intra}) backend={self.backend}{extra} "
                f"spec={self.spec.describe()}")


# --------------------------------------------------------- n-block choice


def _resolve_hier_blocks(kind: str, spec: PayloadSpec, nodes: int, cores: int,
                         n_inter: Optional[int], n_intra: Optional[int],
                         inter_model: CommModel,
                         intra_model: CommModel) -> Tuple[int, int]:
    p = nodes * cores
    elems, total = [], 0
    for shape, dtype in spec.leaves:
        if kind == "allgather":
            _require(len(shape) >= 1 and shape[0] % p == 0,
                     f"leading dim {shape[0] if shape else 0} not divisible "
                     f"by mesh size {nodes}x{cores}={p}")
            e = (shape[0] // p) * _leaf_elems(shape[1:])
        else:
            _require(len(shape) >= 1 and shape[0] == p,
                     "payload leaves must have leading axis == nodes*cores "
                     f"(one slice/rank); got {shape} for {nodes}x{cores}")
            e = _leaf_elems(shape[1:])
        elems.append(e)
        total += e * np.dtype(dtype).itemsize
    if kind == "allgather":
        # Inter level exchanges node blocks (the full p*e payload);
        # intra only the node's share.
        m_inter, m_intra = total * p, total * cores
    else:
        m_inter = m_intra = total
    auto_n, auto_c = optimal_hier_blocks(nodes, cores, m_inter, m_intra,
                                         inter_model, intra_model, kind=kind)
    cap = max(1, max(elems))
    if kind == "allgather":
        cap_intra = cap              # per-rank contribution elems
        cap_inter = cap * cores      # node-block elems
    else:
        cap_intra = cap_inter = cap
    nN = min(max(1, n_inter or auto_n), cap_inter)
    nC = min(max(1, n_intra or auto_c), cap_intra)
    return nN, nC


# ---------------------------------------------------------------- the comm


@dataclass(frozen=True)
class HierComm:
    """Two-level hierarchical communicator over a (nodes x cores) mesh.

    Binds the static context once: the 2D ``mesh``, the ``inter_axis``
    (nodes) and ``intra_axis`` (cores) names, the round-step
    ``backend``, and one :class:`~repro.core.costmodel.CommModel` per
    level (the whole point of going hierarchical: the inter-node links
    are priced differently from the intra-node ones).  ``plan``
    precomputes a :class:`HierPlan`; the named collectives are thin
    plan-cache lookups.  Frozen and hashable.
    """

    mesh: Mesh
    inter_axis: str
    intra_axis: str
    backend: str = "jnp"
    inter_model: CommModel = DEFAULT_MODEL
    intra_model: CommModel = DEFAULT_MODEL

    def __post_init__(self):
        for axis in (self.inter_axis, self.intra_axis):
            if axis not in self.mesh.shape:
                raise ValueError(f"axis {axis!r} not in mesh axes "
                                 f"{tuple(self.mesh.shape)}")
        if self.inter_axis == self.intra_axis:
            raise ValueError("inter_axis and intra_axis must differ, got "
                             f"{self.inter_axis!r} twice")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown round-step backend {self.backend!r} "
                             f"(use one of {BACKENDS})")

    @property
    def nodes(self) -> int:
        return self.mesh.shape[self.inter_axis]

    @property
    def cores(self) -> int:
        return self.mesh.shape[self.intra_axis]

    @property
    def p(self) -> int:
        return self.nodes * self.cores

    # ------------------------------------------------------------- planning

    def plan(self, kind: str, spec: Any, *,
             n_inter: Optional[int] = None, n_intra: Optional[int] = None,
             root: int = 0, op: str = "sum") -> HierPlan:
        """Precompute a :class:`HierPlan` for ``kind`` and a payload spec.

        ``root`` is the flat node-major rank ``node * cores + core``.
        ``n_inter`` / ``n_intra`` override the per-level cost-model
        optima.  Cached process-wide; equal arguments return the
        identical plan object.
        """
        if kind not in HIER_KINDS:
            raise ValueError(f"unknown hier kind {kind!r} "
                             f"(use one of {HIER_KINDS})")
        kind = _CANONICAL_KIND.get(kind, kind)
        spec = payload_spec(spec)
        _require(spec.num_leaves > 0, "payload has no array leaves")
        rooted = kind in ("broadcast", "reduce", "allreduce")
        reducing = kind in ("reduce", "allreduce")
        _require(rooted or int(root) == 0,
                 f"root= does not apply to hier kind {kind!r}")
        _require(reducing or op == "sum",
                 f"op= does not apply to hier kind {kind!r}")
        _require(0 <= int(root) < self.p,
                 f"root must be in [0, nodes*cores), got {root} for "
                 f"{self.nodes}x{self.cores}")
        root_key = int(root) if rooted else 0
        op_key = op if reducing else None
        nN, nC = self._resolve_n(kind, spec, n_inter, n_intra)
        key = ("hierplan", self.mesh, self.inter_axis, self.intra_axis,
               self.backend, self.inter_model, self.intra_model, kind, spec,
               nN, nC, root_key, op_key)
        return cached_plan(key, lambda: self._build(
            kind, spec, nN, nC, root_key, op_key))

    def _resolve_n(self, kind: str, spec: PayloadSpec,
                   n_inter: Optional[int],
                   n_intra: Optional[int]) -> Tuple[int, int]:
        if self.p == 1:
            return max(1, n_inter or 1), max(1, n_intra or 1)
        return _resolve_hier_blocks(kind, spec, self.nodes, self.cores,
                                    n_inter, n_intra, self.inter_model,
                                    self.intra_model)

    def _build(self, kind: str, spec: PayloadSpec, nN: int, nC: int,
               root: int, op: Optional[str]) -> HierPlan:
        nodes, cores = self.nodes, self.cores
        if op is not None:
            from repro.kernels.reduce_ops import op_identity

            op_identity(op, np.float32)  # host-side op validation
        rN = num_rounds(nodes, nN)
        rC = num_rounds(cores, nC)
        scale = 2 if kind == "allreduce" else 1
        common = dict(kind=kind, spec=spec, nodes=nodes, cores=cores,
                      root=root, op=op, n_inter=nN, n_intra=nC,
                      rounds=scale * (rN + rC), rounds_inter=scale * rN,
                      rounds_intra=scale * rC, backend=self.backend,
                      inter_axis=self.inter_axis, intra_axis=self.intra_axis)
        if self.p == 1:
            return HierPlan(_execute=None, **common)
        rootN, rootC = divmod(root, cores)
        bN = get_bundle(nodes, rootN)
        bC = get_bundle(cores, rootC)
        ex = _lower_hier(self.mesh, self.inter_axis, self.intra_axis, kind,
                         bN, bC, nN, nC, rootN, rootC, op, self.backend, spec)
        return HierPlan(_execute=jax.jit(ex),
                        statics=_hier_statics(kind, bN, bC, nN, nC,
                                              self.inter_axis,
                                              self.intra_axis),
                        **common)

    # ------------------------------------------------ collective shorthands

    def broadcast(self, x: Any, *, n_inter: Optional[int] = None,
                  n_intra: Optional[int] = None, root: int = 0) -> Any:
        """Leader broadcast + intra fan-out of flat rank ``root``'s slices."""
        return self.plan("broadcast", payload_spec(x), n_inter=n_inter,
                         n_intra=n_intra, root=root)(x)

    def reduce(self, x: Any, *, n_inter: Optional[int] = None,
               n_intra: Optional[int] = None, root: int = 0,
               op: str = "sum") -> Any:
        """Intra-reduce to the leaders, then inter-reduce to ``root``."""
        return self.plan("reduce", payload_spec(x), n_inter=n_inter,
                         n_intra=n_intra, root=root, op=op)(x)

    def allreduce(self, x: Any, *, n_inter: Optional[int] = None,
                  n_intra: Optional[int] = None, root: int = 0,
                  op: str = "sum") -> Any:
        """Intra-reduce -> inter-allreduce -> intra-broadcast fan-out."""
        return self.plan("allreduce", payload_spec(x), n_inter=n_inter,
                         n_intra=n_intra, root=root, op=op)(x)

    def allgather(self, x: Any, *, n_inter: Optional[int] = None,
                  n_intra: Optional[int] = None) -> Any:
        """Two-phase all-to-all broadcast; replicated rank-major result."""
        return self.plan("allgather", payload_spec(x), n_inter=n_inter,
                         n_intra=n_intra)(x)


def get_hier_comm(mesh: Mesh, inter_axis: str, intra_axis: str, *,
                  backend: str = "jnp",
                  inter_model: CommModel = DEFAULT_MODEL,
                  intra_model: CommModel = DEFAULT_MODEL) -> HierComm:
    """The process-cached :class:`HierComm` for this context (identity is
    stable while cached, like :func:`repro.core.comm.get_comm`)."""
    return cached_plan(
        ("hiercomm", mesh, inter_axis, intra_axis, backend, inter_model,
         intra_model),
        lambda: HierComm(mesh=mesh, inter_axis=inter_axis,
                         intra_axis=intra_axis, backend=backend,
                         inter_model=inter_model, intra_model=intra_model))


# ------------------------------------------------------ functional wrappers


def hier_broadcast(mesh: Mesh, inter_axis: str, intra_axis: str, x: Any, *,
                   n_inter: Optional[int] = None,
                   n_intra: Optional[int] = None, root: int = 0,
                   backend: str = "jnp") -> Any:
    """One-call hierarchical broadcast (plan-cache lookup under the hood)."""
    return get_hier_comm(mesh, inter_axis, intra_axis,
                         backend=backend).broadcast(
        x, n_inter=n_inter, n_intra=n_intra, root=root)


def hier_reduce(mesh: Mesh, inter_axis: str, intra_axis: str, x: Any, *,
                n_inter: Optional[int] = None, n_intra: Optional[int] = None,
                root: int = 0, op: str = "sum", backend: str = "jnp") -> Any:
    """One-call hierarchical reduction to flat rank ``root``."""
    return get_hier_comm(mesh, inter_axis, intra_axis,
                         backend=backend).reduce(
        x, n_inter=n_inter, n_intra=n_intra, root=root, op=op)


def hier_allreduce(mesh: Mesh, inter_axis: str, intra_axis: str, x: Any, *,
                   n_inter: Optional[int] = None,
                   n_intra: Optional[int] = None, root: int = 0,
                   op: str = "sum", backend: str = "jnp") -> Any:
    """One-call hierarchical all-reduction."""
    return get_hier_comm(mesh, inter_axis, intra_axis,
                         backend=backend).allreduce(
        x, n_inter=n_inter, n_intra=n_intra, root=root, op=op)


def hier_allgather(mesh: Mesh, inter_axis: str, intra_axis: str, x: Any, *,
                   n_inter: Optional[int] = None,
                   n_intra: Optional[int] = None,
                   backend: str = "jnp") -> Any:
    """One-call hierarchical allgather (replicated rank-major result)."""
    return get_hier_comm(mesh, inter_axis, intra_axis,
                         backend=backend).allgather(
        x, n_inter=n_inter, n_intra=n_intra)


# ----------------------------------------------------- host data plans
#
# Single-process executions of the two-level data plane, composing the
# cached per-level host plans of repro.core.comm: phase A runs the
# level's kernels with the level's ranks batched on the kernel rows,
# the host-side re-blocking seam matches the device lowering's
# flatten/split, and phase B consumes phase A's output.  The simulator
# asserts these bit-exact against its message-passing reference -- the
# hierarchical certification path for both backends on CPU CI, at the
# full 36x32 scale no local device mesh could reach.


def _split_np(flat: np.ndarray, n: int) -> np.ndarray:
    """Host-side mirror of the device re-blocking: [m] -> [n, ceil(m/n)]."""
    flat = np.asarray(flat).reshape(-1)
    bs = -(-flat.shape[0] // n)
    out = np.zeros((n, bs), flat.dtype)
    out.reshape(-1)[: flat.shape[0]] = flat
    return out


def _reduce_sweep(values, nodes, cores, n_inter, n_intra, intra_red,
                  inter_red, root_node, root_core):
    """Host reduction sweep: [nodes, cores, m] contributions -> the flat
    [m] op-reduction at the root, via per-node intra reductions to the
    leaders then one inter reduction (a one-rank level passes through).
    Shared by the reduce and allreduce host plans."""
    vals = np.asarray(values).reshape(nodes, cores, -1)
    m = vals.shape[-1]
    if intra_red is not None:
        parts = []
        for j in range(nodes):
            blocked = np.stack([_split_np(vals[j, c], n_intra)
                                for c in range(cores)])
            parts.append(intra_red.run(blocked)[root_core].reshape(-1)[:m])
        partials = np.stack(parts)                    # [nodes, m]
    else:
        partials = vals[:, 0]
    if inter_red is not None:
        blocked = np.stack([_split_np(partials[j], n_inter)
                            for j in range(nodes)])
        return inter_red.run(blocked)[root_node].reshape(-1)[:m]
    return partials[0]


def _bcast_sweep(values, nodes, cores, n_inter, n_intra, inter_bc, intra_bc):
    """Host broadcast sweep: flat [m] payload at the root -> the final
    [nodes, cores, m] state of every rank, via the inter-node leader
    broadcast then the (node-identical) intra fan-out.  Per-level
    agreement of the leader copies is asserted.  Shared by the
    broadcast and allreduce host plans."""
    vals = np.asarray(values).reshape(-1)
    m = vals.shape[0]
    leader = vals
    if inter_bc is not None:
        got = inter_bc.run(_split_np(vals, n_inter))
        # every node leader ends with the root's payload
        leader = got[0].reshape(-1)[:m]
        for j in range(nodes):
            assert np.array_equal(got[j].reshape(-1)[:m], leader), (
                f"hier broadcast sweep: node leader {j} diverged")
    if intra_bc is not None:
        got = intra_bc.run(_split_np(leader, n_intra))
        percore = np.stack([got[c].reshape(-1)[:m] for c in range(cores)])
    else:
        percore = leader[None]
    return np.broadcast_to(percore[None], (nodes, cores, m))


@dataclass(frozen=True, eq=False)
class HierHostPlan:
    """Precomputed hierarchical host-side data-plane execution.

    Composes the cached flat :class:`~repro.core.comm.HostDataPlan`\\ s
    of each level; ``run(values)`` executes only the per-level rounds
    plus the re-blocking seam.
    """

    kind: str
    nodes: int
    cores: int
    n_inter: int
    n_intra: int
    root: int
    op: Optional[str]
    backend: str
    inter: Any = field(repr=False)   # flat HostDataPlan or None (level of 1)
    intra: Any = field(repr=False)

    @property
    def root_node(self) -> int:
        return self.root // self.cores

    @property
    def root_core(self) -> int:
        return self.root % self.cores

    @property
    def statics(self) -> Tuple[PhaseStatic, ...]:
        """Composed per-phase audit records in run order, delegated to
        the per-level flat host plans (a one-rank level contributes
        nothing)."""
        inter = self.inter.statics if self.inter is not None else ()
        intra = self.intra.statics if self.intra is not None else ()
        return inter + intra if self.kind == "broadcast" else intra + inter

    def run(self, values: np.ndarray) -> np.ndarray:
        if self.kind == "broadcast":
            return self._run_broadcast(values)
        if self.kind == "reduce":
            return self._run_reduce(values)
        # allreduce is always built as _AllreduceHostPlan (its levels
        # hold (reduce, broadcast) plan pairs this base class cannot run)
        assert self.kind == "allgather", self.kind
        return self._run_allgather(values)

    def _run_broadcast(self, values: np.ndarray) -> np.ndarray:
        """``values``: flat [m] payload at flat rank ``root`` -> final
        [nodes, cores, m] state of every rank."""
        return _bcast_sweep(values, self.nodes, self.cores, self.n_inter,
                            self.n_intra, self.inter, self.intra)

    def _run_reduce(self, values: np.ndarray) -> np.ndarray:
        """``values``: [nodes, cores, m] contributions -> flat [m]
        op-reduction (the state of flat rank ``root``)."""
        return _reduce_sweep(values, self.nodes, self.cores, self.n_inter,
                             self.n_intra, self.intra, self.inter,
                             self.root_node, self.root_core)

    def _run_allgather(self, values: np.ndarray) -> np.ndarray:
        """``values``: [nodes, cores, e] contributions -> flat
        [nodes*cores, e] rank-major gathered result (identical on every
        rank; per-level agreement asserted)."""
        vals = np.asarray(values).reshape(self.nodes, self.cores, -1)
        e = vals.shape[-1]
        if self.intra is not None:
            blocks = []
            for j in range(self.nodes):
                blocked = np.stack([_split_np(vals[j, c], self.n_intra)
                                    for c in range(self.cores)])
                got = self.intra.run(blocked)         # [C_rank, C_root, n, bs]
                node_block = got[0].reshape(self.cores, -1)[:, :e]
                for c in range(1, self.cores):
                    assert np.array_equal(
                        got[c].reshape(self.cores, -1)[:, :e], node_block), (
                        f"hier allgather: node {j} rank {c} diverged")
                blocks.append(node_block.reshape(-1))  # [cores * e]
            node_blocks = np.stack(blocks)            # [nodes, cores*e]
        else:
            node_blocks = vals[:, 0]
        if self.inter is not None:
            blocked = np.stack([_split_np(node_blocks[j], self.n_inter)
                                for j in range(self.nodes)])
            got = self.inter.run(blocked)             # [N_rank, N_root, n, bs]
            sz = node_blocks.shape[-1]
            out = got[0].reshape(self.nodes, -1)[:, :sz]
            for r in range(1, self.nodes):
                assert np.array_equal(
                    got[r].reshape(self.nodes, -1)[:, :sz], out), (
                    f"hier allgather: inter rank {r} diverged")
        else:
            out = node_blocks
        return out.reshape(self.nodes * self.cores, e)


def hier_host_plan(kind: str, nodes: int, cores: int, n_inter: int,
                   n_intra: int, *, root: int = 0, op: str = "sum",
                   backend: str = "jnp",
                   interpret: Optional[bool] = None) -> HierHostPlan:
    """The cached :class:`HierHostPlan` for a two-level certification
    execution.  ``kind``: broadcast / reduce / allreduce / allgather.
    Equal arguments return the identical plan object."""
    kind = _CANONICAL_KIND.get(kind, kind)
    if kind not in ("broadcast", "reduce", "allreduce", "allgather"):
        raise ValueError(f"unknown hier host data-plane kind {kind!r}")
    nodes, cores = int(nodes), int(cores)
    rooted = kind in ("broadcast", "reduce", "allreduce")
    root_key = int(root) if rooted else 0
    if not 0 <= root_key < max(1, nodes * cores):
        raise ValueError(f"root must be in [0, nodes*cores), got {root} for "
                         f"{nodes}x{cores}")
    op_key = op if kind in ("reduce", "allreduce") else None
    key = ("hierhostplan", kind, nodes, cores, int(n_inter), int(n_intra),
           root_key, op_key, backend, interpret)

    def build():
        rootN, rootC = divmod(root_key, cores)
        flat_kind = "allgather" if kind == "allgather" else (
            "reduce" if kind == "reduce" else "broadcast")

        def level(p, n, level_root):
            if p == 1:
                return None
            if flat_kind == "allgather":
                return host_plan("allgather", p, n, backend=backend,
                                 interpret=interpret)
            if flat_kind == "reduce":
                return host_plan("reduce", p, n, root=level_root, op=op_key,
                                 backend=backend, interpret=interpret)
            return host_plan("broadcast", p, n, root=level_root,
                             backend=backend, interpret=interpret)

        if kind == "allreduce":
            # the composed run needs both directions; cache the four flat
            # plans eagerly so run() is pure execution.
            inter = (host_plan("reduce", nodes, n_inter, root=rootN,
                               op=op_key, backend=backend,
                               interpret=interpret),
                     host_plan("broadcast", nodes, n_inter, root=rootN,
                               backend=backend, interpret=interpret)
                     ) if nodes > 1 else None
            intra = (host_plan("reduce", cores, n_intra, root=rootC,
                               op=op_key, backend=backend,
                               interpret=interpret),
                     host_plan("broadcast", cores, n_intra, root=rootC,
                               backend=backend, interpret=interpret)
                     ) if cores > 1 else None
            return _AllreduceHostPlan(
                kind=kind, nodes=nodes, cores=cores, n_inter=int(n_inter),
                n_intra=int(n_intra), root=root_key, op=op_key,
                backend=backend, inter=inter, intra=intra)
        return HierHostPlan(
            kind=kind, nodes=nodes, cores=cores, n_inter=int(n_inter),
            n_intra=int(n_intra), root=root_key, op=op_key, backend=backend,
            inter=level(nodes, n_inter, rootN),
            intra=level(cores, n_intra, rootC))

    return cached_plan(key, build)


@dataclass(frozen=True, eq=False)
class _AllreduceHostPlan(HierHostPlan):
    """Hier allreduce host plan: per level, ``inter``/``intra`` hold a
    (reduce_plan, broadcast_plan) pair instead of one flat plan; the
    run is the reduction sweep followed by the broadcast sweep."""

    @property
    def statics(self) -> Tuple[PhaseStatic, ...]:
        red_n, bc_n = self.inter if self.inter is not None else (None, None)
        red_c, bc_c = self.intra if self.intra is not None else (None, None)
        out: Tuple[PhaseStatic, ...] = ()
        for plan in (red_c, red_n, bc_n, bc_c):  # the composed run order
            if plan is not None:
                out = out + plan.statics
        return out

    def run(self, values: np.ndarray) -> np.ndarray:
        red_n, bc_n = self.inter if self.inter is not None else (None, None)
        red_c, bc_c = self.intra if self.intra is not None else (None, None)
        total = _reduce_sweep(values, self.nodes, self.cores, self.n_inter,
                              self.n_intra, red_c, red_n,
                              self.root_node, self.root_core)
        return _bcast_sweep(total, self.nodes, self.cores, self.n_inter,
                            self.n_intra, bc_n, bc_c)
