"""Pluggable per-round data plane for the collective family.

The paper deliberately separates the O(log p) *schedule computation*
from the per-round *data movement*, and the whole collective family
(broadcast / all-broadcast / reduction / all-reduction, arXiv:2407.18004)
shares one per-round inner step on its block buffers:

  * broadcast family: ``pack`` one block per row into the outgoing
    message -> exchange -> ``unpack`` into one slot per row;
  * reduce family: capture the forwarded partial and drain its slot ->
    exchange -> ``accumulate`` the incoming partial (sum/max).

:class:`RoundStep` is that step as a small backend interface.  Buffers
are ``[R, nslots, bs]`` arrays (R rows: one per rank in the batched
simulator data plane, one per root in the all-gather family, a single
row inside a per-rank ``shard_map`` body); slot vectors are ``[R]``
int32 columns of the engine's per-round tables
(:meth:`ScheduleBundle.per_round_tables` /
:meth:`ScheduleBundle.reversed_per_round_tables`).

Two backends implement it:

  * ``"jnp"`` -- the pure-jnp reference (:mod:`repro.kernels.ref`):
    gathers and ``.at[]`` scatters; lowers everywhere, used by default;
  * ``"pallas"`` -- the fused Pallas kernels
    (:mod:`repro.kernels.block_pack`): scalar-prefetched schedule
    columns drive BlockSpec index maps, so block selection is pure DMA
    index mapping; compiled on TPU, ``interpret=True`` elsewhere.

Both backends implement identical update order (unpack-then-pack;
accumulate-then-capture-then-drain), so they agree **bit-exactly** --
asserted by the simulator certification harness
(:func:`dataplane_broadcast` / :func:`dataplane_reduce` /
:func:`dataplane_allgather`, wired into ``simulate_*(backend=...)``)
and by the backend-parametrized collective tests.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "RoundStep",
    "JnpRoundStep",
    "PallasRoundStep",
    "get_round_step",
    "clamp_slots",
    "broadcast_slot_plan",
    "reduce_slot_plan",
    "scatter_slot_plan",
    "PhaseStatic",
    "broadcast_phase_static",
    "allgather_phase_static",
    "reduce_phase_static",
    "scatter_phase_static",
    "dataplane_broadcast",
    "dataplane_allgather",
    "dataplane_reduce",
    "dataplane_hier_broadcast",
    "dataplane_hier_reduce",
    "dataplane_hier_allreduce",
    "dataplane_hier_allgather",
]

BACKENDS = ("jnp", "pallas")


# ------------------------------------------------------------ slot plans
#
# Slot plans are cached process-wide in the engine's spec-keyed plan
# cache (keyed on (p, root, n) -- bundles are themselves cached, so the
# bundle identity is implied by the key).  The returned arrays are
# immutable and shared: a CollectivePlan holds them for its lifetime,
# and repeated per-call lowering (the legacy circulant_* path) pays the
# clamping exactly once per process.


def clamp_slots(eff: np.ndarray, n: int, garbage: Optional[int] = None) -> np.ndarray:
    """Effective block indices -> buffer slots: negative ("idle this
    round") entries address the garbage slot, entries > n-1 are capped
    to n-1 (final-phase re-sends), exactly as in Algorithm 1."""
    g = n if garbage is None else garbage
    return np.where(eff < 0, g, np.minimum(eff, n - 1)).astype(np.int32)


def _frozen(*arrays: np.ndarray) -> Tuple[np.ndarray, ...]:
    for a in arrays:
        a.setflags(write=False)
    return arrays


def broadcast_slot_plan(bundle, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(recv_slots, send_slots, ks): clamped [R, p] forward slot tables.

    Row t is the slot column of forward round t; buffers carry ``n+1``
    slots with slot ``n`` the garbage slot (Correctness Condition 1
    guarantees sender and receiver address garbage in the same rounds).
    Cached process-wide; the returned arrays are immutable and shared.
    """
    from .engine import cached_plan

    def build():
        recv_eff, send_eff, ks = bundle.per_round_tables(n)
        return _frozen(clamp_slots(recv_eff, n), clamp_slots(send_eff, n), ks)

    return cached_plan(("slots/bcast", bundle.p, bundle.root, int(n)), build)


def reduce_slot_plan(bundle, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(fwd_slots, acc_slots, ks): clamped [R, p] reversed slot tables.

    Buffers carry ``n+2`` slots: slot ``n`` is garbage, slot ``n+1``
    holds the op identity and is never overwritten with data.  The root
    never forwards a partial (forward rounds never send TO the root, so
    reversed rounds never send FROM it) -- its fwd column is pinned to
    the identity slot, so capped final-phase entries ship the identity
    instead of a live partial.  Cached process-wide; immutable arrays.
    """
    from .engine import cached_plan

    def build():
        fwd_eff, acc_eff, ks = bundle.reversed_per_round_tables(n)
        fwd = clamp_slots(fwd_eff, n)
        fwd[:, bundle.root] = n + 1
        return _frozen(fwd, clamp_slots(acc_eff, n), ks)

    return cached_plan(("slots/reduce", bundle.p, bundle.root, int(n)), build)


def scatter_slot_plan(bundle, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(fwd_slots, acc_slots, ks): clamped reversed tables *without* the
    root identity-slot pinning -- the reduce-scatter form, where capped
    final-phase entries are real deliveries routed by drain-after-send
    (buffers carry ``n+1`` slots, slot ``n`` garbage).  Cached."""
    from .engine import cached_plan

    def build():
        fwd_eff, acc_eff, ks = bundle.reversed_per_round_tables(n)
        return _frozen(clamp_slots(fwd_eff, n), clamp_slots(acc_eff, n), ks)

    return cached_plan(("slots/scatter", bundle.p, bundle.root, int(n)), build)


# ------------------------------------------------------- phase statics
#
# A PhaseStatic is the auditable description of one schedule phase: the
# exact clamped slot tables a plan's executor closed over (the cached
# arrays themselves, by identity), the skip-column sequence and the
# per-round wire rotations.  Plans of every flavour (device
# CollectivePlan / HierPlan, host HostDataPlan / HierHostPlan) expose a
# ``statics`` tuple of these, which repro.analysis.planaudit checks
# against the bundle and the closed-form round counts without running a
# single round.


@dataclass(frozen=True, eq=False)
class PhaseStatic:
    """Static per-phase audit record (see :mod:`repro.analysis`).

    ``kind`` is the phase family (``"broadcast"``, ``"allgather"``,
    ``"reduce"``, ``"scatter"``); ``direction`` is ``"fwd"`` for
    broadcast-direction phases and ``"rev"`` for reversed (reduction)
    phases.  ``slots`` holds the clamped [R, p] tables in execution
    order -- ``(recv, send)`` forward, ``(fwd, acc)`` reversed,
    ``(recv,)`` for the allgather family -- and ``shifts[t]`` is the
    signed-free rotation applied on the wire in round t (rank r sends to
    ``(r + shifts[t]) % p``).  ``nslots`` is the buffer slot count the
    tables address (n+1, or n+2 for the identity-pinned reduce layout).
    """

    kind: str
    direction: str
    p: int
    root: int
    n: int
    nslots: int
    slots: Tuple[np.ndarray, ...]
    ks: np.ndarray
    shifts: Tuple[int, ...]
    axis: Optional[str] = None
    #: True when the executor runs the overlapped (double-buffered) round
    #: loop: round t+1's block is packed from the pre-update buffer while
    #: round t's exchange is in flight, then patched by the staged step.
    #: The auditor additionally proves the staleness condition on these.
    overlap: bool = False


def broadcast_phase_static(bundle, n: int, axis: Optional[str] = None,
                           overlap: bool = False) -> PhaseStatic:
    """Audit record of a forward broadcast phase (cached tables shared)."""
    recv, send, ks = broadcast_slot_plan(bundle, n)
    shifts = tuple(int(bundle.skip[int(k)]) for k in ks)
    return PhaseStatic(kind="broadcast", direction="fwd", p=bundle.p,
                       root=bundle.root, n=int(n), nslots=int(n) + 1,
                       slots=(recv, send), ks=ks, shifts=shifts, axis=axis,
                       overlap=overlap)


def allgather_phase_static(bundle, n: int, axis: Optional[str] = None,
                           overlap: bool = False) -> PhaseStatic:
    """Audit record of an all-to-all broadcast phase: only the receive
    table is static per rank (send slots are derived per root row via
    Condition 2's base rotation at run time)."""
    recv, _send, ks = broadcast_slot_plan(bundle, n)
    shifts = tuple(int(bundle.skip[int(k)]) for k in ks)
    return PhaseStatic(kind="allgather", direction="fwd", p=bundle.p,
                       root=bundle.root, n=int(n), nslots=int(n) + 1,
                       slots=(recv,), ks=ks, shifts=shifts, axis=axis,
                       overlap=overlap)


def reduce_phase_static(bundle, n: int, axis: Optional[str] = None,
                        overlap: bool = False) -> PhaseStatic:
    """Audit record of a reversed reduction phase (identity-pinned root
    column, n+2-slot layout; partials travel against the skips)."""
    fwd, acc, ks = reduce_slot_plan(bundle, n)
    shifts = tuple((bundle.p - int(bundle.skip[int(k)])) % bundle.p
                   for k in ks)
    return PhaseStatic(kind="reduce", direction="rev", p=bundle.p,
                       root=bundle.root, n=int(n), nslots=int(n) + 2,
                       slots=(fwd, acc), ks=ks, shifts=shifts, axis=axis,
                       overlap=overlap)


def scatter_phase_static(bundle, n: int, axis: Optional[str] = None,
                         overlap: bool = False) -> PhaseStatic:
    """Audit record of a reduce-scatter phase (unpinned reversed tables,
    n+1-slot layout with drain-after-send routing)."""
    fwd, acc, ks = scatter_slot_plan(bundle, n)
    shifts = tuple((bundle.p - int(bundle.skip[int(k)])) % bundle.p
                   for k in ks)
    return PhaseStatic(kind="scatter", direction="rev", p=bundle.p,
                       root=bundle.root, n=int(n), nslots=int(n) + 1,
                       slots=(fwd, acc), ks=ks, shifts=shifts, axis=axis,
                       overlap=overlap)


# ------------------------------------------------------------- interface


class RoundStep:
    """One collective round's data movement on [R, nslots, bs] buffers.

    ``pack``/``unpack`` are the plain first/last-round primitives;
    ``shuffle`` fuses unpack(t) + pack(t+1) for the broadcast family and
    ``acc_shuffle`` fuses accumulate(t) + capture/drain(t+1) for the
    reduce family -- one backend call per steady-state round.
    """

    backend: str

    def pack(self, buf, idx):
        """[R, S, B], [R] -> [R, B]: out[r] = buf[r, idx[r]]."""
        raise NotImplementedError

    def unpack(self, buf, msg, idx):
        """buf[r, idx[r]] = msg[r]; untouched slots keep contents."""
        raise NotImplementedError

    def shuffle(self, buf, msg, recv_idx, send_idx):
        """Fused unpack+pack -> (new_buf, out_msg); the pack reads the
        *updated* buffer (pipeline: forward next what was just received)."""
        raise NotImplementedError

    def shuffle_staged(self, buf, msg, pre, recv_idx, send_idx):
        """Overlap-staged shuffle -> (new_buf, out_msg): ``pre`` is the
        next send block packed from the PRE-update buffer (computable
        while the exchange is in flight); the step writes msg into the
        recv slots and patches the one stale case recv == send.
        Bit-exact vs :meth:`shuffle` under the write-once invariant."""
        raise NotImplementedError

    def acc_shuffle(self, buf, msg, acc_idx, fwd_idx, *, op: str = "sum"):
        """Fused accumulate+capture/drain -> (new_buf, out_msg):
        buf[acc] op= msg, then out = buf[fwd] (post-accumulate when the
        slots coincide), then buf[fwd] = identity(op, dtype)."""
        raise NotImplementedError

    def acc_shuffle_staged(self, buf, msg, pre, acc_idx, fwd_idx, *,
                           op: str = "sum"):
        """Overlap-staged acc_shuffle -> (new_buf, out_msg): ``pre`` is
        the next fwd block packed from the PRE-accumulate buffer; the
        step accumulates, patches the coincident fwd == acc case with
        the combined value, and drains.  Bit-exact vs
        :meth:`acc_shuffle`."""
        raise NotImplementedError

    def qacc_shuffle(self, buf, err, qmsg, smsg, acc_idx, fwd_idx):
        """Quantized-wire acc_shuffle (sum only) -> (new_buf, new_err,
        out_q, out_s): dequantize (qmsg, smsg) and accumulate into
        buf[acc], requantize the captured buf[fwd] for the wire,
        accumulate its requantization error into err[fwd], drain
        buf[fwd] to zero."""
        raise NotImplementedError


class JnpRoundStep(RoundStep):
    """Pure-jnp reference backend (gathers + ``.at[]`` scatters).

    Methods go through process-cached ``jax.jit`` wrappers, so eager
    host-side use (the simulator data plane) amortizes tracing across
    the sweep; inside an enclosing jit/shard_map trace they inline.
    """

    backend = "jnp"

    def pack(self, buf, idx):
        return _jnp_call("block_pack_ref", buf, idx)

    def unpack(self, buf, msg, idx):
        return _jnp_call("block_unpack_ref", buf, msg, idx)

    def shuffle(self, buf, msg, recv_idx, send_idx):
        return _jnp_call("block_shuffle_ref", buf, msg, recv_idx, send_idx)

    def shuffle_staged(self, buf, msg, pre, recv_idx, send_idx):
        return _jnp_call("block_shuffle_staged_ref", buf, msg, pre,
                         recv_idx, send_idx)

    def acc_shuffle(self, buf, msg, acc_idx, fwd_idx, *, op: str = "sum"):
        return _jnp_call("block_acc_shuffle_ref", buf, msg, acc_idx, fwd_idx,
                         op=op)

    def acc_shuffle_staged(self, buf, msg, pre, acc_idx, fwd_idx, *,
                           op: str = "sum"):
        return _jnp_call("block_acc_shuffle_staged_ref", buf, msg, pre,
                         acc_idx, fwd_idx, op=op)

    def qacc_shuffle(self, buf, err, qmsg, smsg, acc_idx, fwd_idx):
        return _jnp_call("block_qacc_shuffle_ref", buf, err, qmsg, smsg,
                         acc_idx, fwd_idx)


_jnp_jits = {}


def _jnp_call(name, *args, **static):
    key = (name, tuple(sorted(static.items())))
    if key not in _jnp_jits:
        import functools

        import jax

        from repro.kernels import ref

        fn = getattr(ref, name)
        _jnp_jits[key] = jax.jit(functools.partial(fn, **static) if static
                                 else fn)
    return _jnp_jits[key](*args)


class PallasRoundStep(RoundStep):
    """Pallas fast path: scalar-prefetched schedule columns select the
    HBM blocks to DMA.  ``interpret=None`` auto-detects the platform
    (compiled on TPU, interpret-mode on CPU CI).  Calls route through
    the jit'd :mod:`repro.kernels.ops` wrappers, so eager host-side use
    hits the compile cache."""

    backend = "pallas"

    def __init__(self, interpret: Optional[bool] = None):
        from repro.kernels.ops import resolve_interpret

        self.interpret = resolve_interpret(interpret)

    def pack(self, buf, idx):
        from repro.kernels.ops import schedule_pack

        return schedule_pack(buf, idx, interpret=self.interpret)

    def unpack(self, buf, msg, idx):
        from repro.kernels.ops import schedule_unpack

        return schedule_unpack(buf, msg, idx, interpret=self.interpret)

    def shuffle(self, buf, msg, recv_idx, send_idx):
        from repro.kernels.ops import schedule_shuffle

        return schedule_shuffle(buf, msg, recv_idx, send_idx,
                                interpret=self.interpret)

    def shuffle_staged(self, buf, msg, pre, recv_idx, send_idx):
        from repro.kernels.ops import schedule_shuffle_staged

        return schedule_shuffle_staged(buf, msg, pre, recv_idx, send_idx,
                                       interpret=self.interpret)

    def acc_shuffle(self, buf, msg, acc_idx, fwd_idx, *, op: str = "sum"):
        from repro.kernels.ops import schedule_acc_shuffle

        return schedule_acc_shuffle(buf, msg, acc_idx, fwd_idx, op=op,
                                    interpret=self.interpret)

    def acc_shuffle_staged(self, buf, msg, pre, acc_idx, fwd_idx, *,
                           op: str = "sum"):
        from repro.kernels.ops import schedule_acc_shuffle_staged

        return schedule_acc_shuffle_staged(buf, msg, pre, acc_idx, fwd_idx,
                                           op=op, interpret=self.interpret)

    def qacc_shuffle(self, buf, err, qmsg, smsg, acc_idx, fwd_idx):
        from repro.kernels.ops import schedule_qacc_shuffle

        return schedule_qacc_shuffle(buf, err, qmsg, smsg, acc_idx, fwd_idx,
                                     interpret=self.interpret)


_step_handles = {}


def get_round_step(backend: str = "jnp",
                   interpret: Optional[bool] = None) -> RoundStep:
    """Round-step backend factory: ``"jnp"`` (portable reference) or
    ``"pallas"`` (fused kernels; ``interpret`` as in
    :func:`repro.kernels.ops.resolve_interpret`).

    Handles are stateless and cached per ``(backend, interpret)``, so a
    plan (repro.core.comm) owns the same shared step instance its
    sibling plans use -- no per-call construction or platform sniffing.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown round-step backend {backend!r} (use one of {BACKENDS})"
        )
    key = (backend, interpret)
    step = _step_handles.get(key)
    if step is None:
        step = (JnpRoundStep() if backend == "jnp"
                else PallasRoundStep(interpret))
        _step_handles[key] = step
    return step


# --------------------------------------------- host data-plane executors
#
# Single-process executions of the full collectives with the R rows of
# the batched kernels standing in for the p ranks and the network
# exchange realized as a row rotation (ppermute's rotation r -> (r+s)%p
# is exactly jnp.roll along the rank axis).  The simulator runs these
# next to its message-passing reference and asserts bit-exact agreement
# -- the certification path for the Pallas backend on CPU CI.
#
# The executors live on the cached host plans of :mod:`repro.core.comm`
# (slot tables + step handle precomputed once per (kind, p, n, root,
# op, backend)); these wrappers keep the original one-shot entry points.


def dataplane_broadcast(p: int, n: int, root: int, values: np.ndarray,
                        backend: str,
                        interpret: Optional[bool] = None) -> np.ndarray:
    """Execute the n-block broadcast data plane on host arrays.

    ``values``: [n] (or [n, bs]) block payloads at the root.  Returns
    the final [p, n, bs] data slots of every rank.
    """
    from .comm import host_plan

    return host_plan("broadcast", p, n, root=root, backend=backend,
                     interpret=interpret).run(values)


def dataplane_allgather(p: int, n: int, values: np.ndarray, backend: str,
                        interpret: Optional[bool] = None) -> np.ndarray:
    """Execute the all-to-all broadcast data plane on host arrays.

    ``values``: [p, n] (or [p, n, bs]) -- root j's block payloads.  The
    [p_rank, p_root] buffer grid is flattened rank-major onto the kernel
    rows, so the exchange is a roll by ``skip * p`` flat rows.  Returns
    the final [p_rank, p_root, n, bs] data slots.
    """
    from .comm import host_plan

    return host_plan("allgather", p, n, backend=backend,
                     interpret=interpret).run(values)


def dataplane_reduce(p: int, n: int, root: int, values: np.ndarray, op: str,
                     backend: str,
                     interpret: Optional[bool] = None) -> np.ndarray:
    """Execute the reversed-schedule reduction data plane on host arrays.

    ``values``: [p, n] (or [p, n, bs]) per-rank block contributions.
    Returns the final [p, n, bs] data slots (row ``root`` holds the
    op-reduction; other rows are drained to the identity).
    """
    from .comm import host_plan

    return host_plan("reduce", p, n, root=root, op=op, backend=backend,
                     interpret=interpret).run(values)


# The hierarchical (two-level) variants compose the flat host plans per
# level (repro.core.hier.hier_host_plan); these wrappers keep the
# one-shot entry-point shape of their flat siblings above.


def dataplane_hier_broadcast(nodes: int, cores: int, n_inter: int,
                             n_intra: int, root: int, values: np.ndarray,
                             backend: str,
                             interpret: Optional[bool] = None) -> np.ndarray:
    """Two-level broadcast data plane: flat [m] payload at the flat
    node-major ``root`` -> final [nodes, cores, m] state of every rank."""
    from .hier import hier_host_plan

    return hier_host_plan("broadcast", nodes, cores, n_inter, n_intra,
                          root=root, backend=backend,
                          interpret=interpret).run(values)


def dataplane_hier_reduce(nodes: int, cores: int, n_inter: int, n_intra: int,
                          root: int, values: np.ndarray, op: str,
                          backend: str,
                          interpret: Optional[bool] = None) -> np.ndarray:
    """Two-level reduction data plane: [nodes, cores, m] contributions
    -> the flat [m] op-reduction held by the root."""
    from .hier import hier_host_plan

    return hier_host_plan("reduce", nodes, cores, n_inter, n_intra,
                          root=root, op=op, backend=backend,
                          interpret=interpret).run(values)


def dataplane_hier_allreduce(nodes: int, cores: int, n_inter: int,
                             n_intra: int, root: int, values: np.ndarray,
                             op: str, backend: str,
                             interpret: Optional[bool] = None) -> np.ndarray:
    """Two-level all-reduction data plane: [nodes, cores, m] in ->
    [nodes, cores, m] out, every rank holding the composed reduction."""
    from .hier import hier_host_plan

    return hier_host_plan("allreduce", nodes, cores, n_inter, n_intra,
                          root=root, op=op, backend=backend,
                          interpret=interpret).run(values)


def dataplane_hier_allgather(nodes: int, cores: int, n_inter: int,
                             n_intra: int, values: np.ndarray, backend: str,
                             interpret: Optional[bool] = None) -> np.ndarray:
    """Two-level allgather data plane: [nodes, cores, e] contributions
    -> the replicated [nodes*cores, e] rank-major gathered result."""
    from .hier import hier_host_plan

    return hier_host_plan("allgather", nodes, cores, n_inter, n_intra,
                          backend=backend, interpret=interpret).run(values)
