"""Core: round-optimal n-block broadcast schedules (Träff 2023) in O(log p),
plus the reversed-schedule collective family (reduction / all-reduction /
all-broadcast, arXiv:2407.18004) on the same cached engine.

Public API (see docs/api.md for the full reference):
    CirculantComm, CollectivePlan, get_comm (plan/execute communicator
    front-end with pytree payloads -- the preferred collective API)
    HierComm, HierPlan, get_hier_comm, hier_rounds (the two-level
    hierarchical layer over a nodes x cores mesh -- the paper's 36x32
    evaluation topology)
    get_bundle, ScheduleBundle (the cached schedule engine)
    RoundStep, get_round_step (the pluggable per-round data plane)
    compute_skips, baseblock, recv_schedule, send_schedule, schedule_tables
    verify_schedules, verify_reversed_schedules, verify_bundle
    simulate_broadcast, simulate_allgather, simulate_allbroadcast,
    simulate_reduce, simulate_allreduce, simulate_hier_broadcast,
    simulate_hier_reduce, simulate_hier_allreduce (all take
    backend="jnp"|"pallas" to certify the round-step data plane
    bit-exactly)
"""

from .comm import CirculantComm, CollectivePlan, get_comm, payload_spec
from .engine import ScheduleBundle, get_bundle
from .hier import (
    HierComm,
    HierPlan,
    get_hier_comm,
    hier_allgather,
    hier_allreduce,
    hier_broadcast,
    hier_host_plan,
    hier_reduce,
    hier_rounds,
)
from .roundstep import PhaseStatic, RoundStep, get_round_step
from .schedule import (
    baseblock,
    ceil_log2,
    compute_skips,
    num_rounds,
    recv_schedule,
    schedule_tables,
    send_schedule,
    virtual_rounds,
)
from .simulator import (
    HierSimResult,
    SimResult,
    simulate_allbroadcast,
    simulate_allgather,
    simulate_allreduce,
    simulate_broadcast,
    simulate_hier_allreduce,
    simulate_hier_broadcast,
    simulate_hier_reduce,
    simulate_reduce,
)
from .verify import (
    verify_bundle,
    verify_p,
    verify_reversed_schedules,
    verify_schedules,
)

__all__ = [
    "CirculantComm",
    "CollectivePlan",
    "get_comm",
    "payload_spec",
    "HierComm",
    "HierPlan",
    "get_hier_comm",
    "hier_broadcast",
    "hier_reduce",
    "hier_allreduce",
    "hier_allgather",
    "hier_host_plan",
    "hier_rounds",
    "ScheduleBundle",
    "get_bundle",
    "PhaseStatic",
    "RoundStep",
    "get_round_step",
    "verify_bundle",
    "baseblock",
    "ceil_log2",
    "compute_skips",
    "num_rounds",
    "recv_schedule",
    "schedule_tables",
    "send_schedule",
    "virtual_rounds",
    "SimResult",
    "HierSimResult",
    "simulate_allbroadcast",
    "simulate_allgather",
    "simulate_allreduce",
    "simulate_broadcast",
    "simulate_reduce",
    "simulate_hier_broadcast",
    "simulate_hier_reduce",
    "simulate_hier_allreduce",
    "verify_p",
    "verify_reversed_schedules",
    "verify_schedules",
]
