"""Architecture registry: one module per assigned architecture.

Each config module defines FULL (the assigned published configuration)
and SMOKE (a reduced same-family configuration for CPU tests).
"""

from importlib import import_module

ARCHS = [
    "zamba2_2p7b",
    "qwen2_0p5b",
    "h2o_danube_1p8b",
    "stablelm_12b",
    "granite_3_2b",
    "llama32_vision_11b",
    "deepseek_v3_671b",
    "deepseek_moe_16b",
    "mamba2_780m",
    "whisper_small",
]

# canonical ids as assigned (hyphenated)
ALIASES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen2-0.5b": "qwen2_0p5b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "stablelm-12b": "stablelm_12b",
    "granite-3-2b": "granite_3_2b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mamba2-780m": "mamba2_780m",
    "whisper-small": "whisper_small",
}


def get_config(name: str, smoke: bool = False):
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.FULL


def all_arch_names():
    return list(ALIASES.keys())
