"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer; the vision
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    n_image_tokens=1601,
    rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family="vlm",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    cross_attn_every=5,
    n_image_tokens=17,
)
