"""mamba2-780m [ssm]: 48L d_model=1536 attn-free, ssm_state=128, SSD
(state-space duality).  [arXiv:2405.21060; unverified]"""

from repro.models.common import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=32),
)
