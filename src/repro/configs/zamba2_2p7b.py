"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560 ssm_state=64 plus a
SHARED attention block (32H, d_ff=10240) applied every 6 layers.
[arXiv:2411.15242; hf]"""

from repro.models.common import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    shared_attn_every=2,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
)
