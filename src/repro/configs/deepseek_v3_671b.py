"""deepseek-v3-671b [moe]: 61L d_model=7168 128H (MLA) expert_d_ff=2048
vocab=129280; 1 shared + 256 routed experts top-8; multi-head latent
attention; multi-token prediction.  [arXiv:2412.19437; hf]"""

from repro.models.common import MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab=129280,
    moe=MoEConfig(
        n_experts=256, top_k=8, n_shared=1, d_expert=2048, capacity_factor=1.25
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    mtp=True,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=64),
    mla=MLAConfig(
        q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
    ),
    mtp=True,
)
