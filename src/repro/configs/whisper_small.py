"""whisper-small [audio encdec]: 12L enc + 12L dec, d_model=768 12H
d_ff=3072 vocab=51865; conv frontend is a STUB (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    encoder_layers=12,
    n_audio_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    encoder_layers=2,
    n_audio_frames=30,
)
