"""Schedule-driven block pack/unpack Pallas kernels (paper Algorithm 2).

The all-to-all broadcast packs, per round, one block per root processor
into a contiguous message: ``tempin[j'] = buffers[j][sendblocks[j][k]]``.
On TPU this is a gather whose indices are the *schedule* -- known before
the kernel runs but data-dependent per rank.  PrefetchScalarGridSpec
passes the index vector as a scalar-prefetch argument so the BlockSpec
index_map can select which HBM block to DMA into VMEM: the pack becomes
pure DMA scheduling, zero compute, exactly matching the paper's
"packing ... bounded by the total size of all buffers" requirement.

``block_unpack`` is the inverse scatter (tempout -> buffers[recvblock]).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(idx_ref, buf_ref, out_ref):
    # the interesting work happened in the index_map DMA; just copy VMEM->VMEM
    out_ref[...] = buf_ref[0]


def block_pack(buffers: jnp.ndarray, idx: jnp.ndarray, *, interpret: bool = True):
    """buffers: [R, nslots, bs]; idx: [R] int32 slot per row -> [R, bs].

    Row r of the output is buffers[r, idx[r]]; the slot choice is the
    send schedule for the round.
    """
    R, nslots, bs = buffers.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, 1, bs), lambda r, idx_ref: (r, idx_ref[r], 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda r, idx_ref: (r, 0)),
    )
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), buffers)


def _unpack_kernel(idx_ref, msg_ref, buf_ref, out_ref):
    del buf_ref  # aliased with the output; untouched slots keep contents
    out_ref[0] = msg_ref[...]


def block_unpack(buffers: jnp.ndarray, msg: jnp.ndarray, idx: jnp.ndarray,
                 *, interpret: bool = True):
    """Scatter msg rows into per-row slots: buffers[r, idx[r]] = msg[r].

    Implemented with an input-output alias so untouched slots keep their
    contents (the receive schedule only writes one slot per round).
    """
    R, nslots, bs = buffers.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda r, idx_ref: (r, 0)),
            pl.BlockSpec((1, 1, bs), lambda r, idx_ref: (r, idx_ref[r], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs), lambda r, idx_ref: (r, idx_ref[r], 0)),
    )
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
        input_output_aliases={2: 0},   # buffers (3rd operand) -> output
        interpret=interpret,
    )(idx.astype(jnp.int32), msg, buffers)