"""Schedule-driven block data-plane Pallas kernels (the per-round hot path).

Every collective in the family runs the same per-round inner step on its
block buffers (paper Algorithms 1-2 and the reversed reduction of
arXiv:2407.18004):

  * broadcast family -- ``pack`` one block per row into the outgoing
    message, exchange, ``unpack`` the incoming message into one slot per
    row;
  * reduce family -- capture the forwarded partial, drain its slot to
    the op identity, exchange, ``accumulate`` the incoming partial.

The block *selection* is the schedule: per-round int32 index vectors
known before the kernel runs but data-dependent per rank / per root row.
``PrefetchScalarGridSpec`` passes them as scalar-prefetch arguments so
every BlockSpec index_map can pick which HBM block to DMA into VMEM --
the pack/unpack becomes pure DMA scheduling with zero real compute,
exactly the paper's "packing ... bounded by the total size of all
buffers" requirement.

Two *fused* kernels cover the steady state with one ``pallas_call`` per
round instead of two:

  * :func:`block_shuffle` -- unpack round t's received message, then
    pack round t+1's outgoing block from the *updated* buffer (the
    pipeline case "forward next what you just received" falls out of the
    in-kernel write-then-select ordering);
  * :func:`block_acc_shuffle` -- accumulate round t's incoming partial
    (sum/max with dtype identities), then capture round t+1's forwarded
    partial and drain its slot to the identity
    (capture-drain-accumulate, see docs/collectives.md).

All kernels run under ``interpret=True`` on CPU CI bit-exactly against
the jnp reference backend (:mod:`repro.core.roundstep`); on TPU the same
code compiles with the index maps lowered to DMA descriptors.  The
fused kernels pass the buffer twice (one read-only operand, one aliased
to the output) so no in-kernel value ever depends on reading back a
block written earlier in the same grid -- the interpret and compiled
modes cannot diverge.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Single source for combine/identity semantics across kernels, the jnp
# oracles and the collectives (re-exported here for consumers that only
# know the kernel module).
from .quant_ops import dequant_blocks, quant_blocks, quant_error
from .reduce_ops import op_combine, op_identity


def default_interpret() -> bool:
    """Auto-detected interpret mode: compiled on TPU, interpreted elsewhere."""
    return jax.default_backend() != "tpu"


def _resolve(interpret):
    return default_interpret() if interpret is None else interpret


# ------------------------------------------------------------------- pack


def _pack_kernel(idx_ref, buf_ref, out_ref):
    # the interesting work happened in the index_map DMA; just copy VMEM->VMEM
    del idx_ref
    out_ref[...] = buf_ref[0]


def block_pack(buffers: jnp.ndarray, idx: jnp.ndarray, *, interpret=None):
    """buffers: [R, nslots, bs]; idx: [R] int32 slot per row -> [R, bs].

    Row r of the output is buffers[r, idx[r]]; the slot choice is the
    send schedule column for the round.
    """
    R, nslots, bs = buffers.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, 1, bs), lambda r, idx_ref: (r, idx_ref[r], 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda r, idx_ref: (r, 0)),
    )
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        interpret=_resolve(interpret),
    )(idx.astype(jnp.int32), buffers)


# ----------------------------------------------------------------- unpack


def _unpack_kernel(idx_ref, msg_ref, buf_ref, out_ref):
    del idx_ref, buf_ref  # aliased with the output; untouched slots keep contents
    out_ref[0] = msg_ref[...]


def block_unpack(buffers: jnp.ndarray, msg: jnp.ndarray, idx: jnp.ndarray,
                 *, interpret=None):
    """Scatter msg rows into per-row slots: buffers[r, idx[r]] = msg[r].

    Implemented with an input-output alias so untouched slots keep their
    contents (the receive schedule only writes one slot per round).
    """
    R, nslots, bs = buffers.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda r, idx_ref: (r, 0)),
            pl.BlockSpec((1, 1, bs), lambda r, idx_ref: (r, idx_ref[r], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs), lambda r, idx_ref: (r, idx_ref[r], 0)),
    )
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
        input_output_aliases={2: 0},   # buffers (3rd operand) -> output
        interpret=_resolve(interpret),
    )(idx.astype(jnp.int32), msg, buffers)


# ------------------------------------------- fused unpack+pack (broadcast)


def _shuffle_kernel(recv_ref, send_ref, msg_ref, ro_ref, alias_ref,
                    outbuf_ref, outmsg_ref):
    r = pl.program_id(0)
    del alias_ref  # aliased with outbuf; untouched slots keep contents
    # unpack: the received message lands in this row's recv slot
    outbuf_ref[...] = msg_ref[...][None]
    # pack from the UPDATED buffer: when the next send slot is the slot
    # just written (the broadcast pipeline "forward what you received"),
    # the outgoing block is the message itself; otherwise it is the
    # DMA-selected old block.  No read-back of a freshly written block.
    same = recv_ref[r] == send_ref[r]
    outmsg_ref[...] = jnp.where(same, msg_ref[...], ro_ref[0, 0])


def block_shuffle(buffers: jnp.ndarray, msg: jnp.ndarray,
                  recv_idx: jnp.ndarray, send_idx: jnp.ndarray,
                  *, interpret=None):
    """Fused unpack(t) + pack(t+1) for the broadcast family.

    buffers: [R, nslots, bs]; msg: [R, bs] received this round;
    recv_idx/send_idx: [R] int32 slots.  Returns ``(new_buffers,
    out_msg)`` where ``new_buffers[r, recv_idx[r]] = msg[r]`` and
    ``out_msg[r] = new_buffers[r, send_idx[r]]`` (i.e. the pack sees the
    unpack's write -- the round-t+1 send of a round-t delivery).
    """
    R, nslots, bs = buffers.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda r, ri, si: (r, 0)),
            # read-only buffer view: the send block (pre-update content)
            pl.BlockSpec((1, 1, bs), lambda r, ri, si: (r, si[r], 0)),
            # aliased buffer: the recv block (overwritten by the kernel)
            pl.BlockSpec((1, 1, bs), lambda r, ri, si: (r, ri[r], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs), lambda r, ri, si: (r, ri[r], 0)),
            pl.BlockSpec((1, bs), lambda r, ri, si: (r, 0)),
        ],
    )
    return pl.pallas_call(
        _shuffle_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
            jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        ],
        input_output_aliases={4: 0},   # 2nd buffer operand -> new_buffers
        interpret=_resolve(interpret),
    )(recv_idx.astype(jnp.int32), send_idx.astype(jnp.int32),
      msg, buffers, buffers)


# ------------------------------------- fused accumulate+capture (reduce)


def _acc_shuffle_kernel(acc_ref, fwd_ref, msg_ref, ro_ref, alias_ref,
                        outbuf_ref, outmsg_ref, scratch_ref, *, op, identity):
    r = pl.program_id(0)
    s = pl.program_id(1)
    # s == 0: accumulate the incoming partial into the acc slot.
    # s == 1: drain the (next round's) fwd slot to the identity.
    # The captured outgoing partial is staged through VMEM scratch at
    # s == 0, computed from pre-update values only (combined when the
    # fwd slot IS the acc slot, the old fwd block otherwise) -- never by
    # reading back a block written earlier in the grid, so interpret and
    # compiled modes agree bit-for-bit.
    combined = op_combine(op)(alias_ref[0, 0], msg_ref[...])

    @pl.when(s == 0)
    def _():
        same = acc_ref[r] == fwd_ref[r]
        scratch_ref[...] = jnp.where(same, combined, ro_ref[0, 0])

    ident = jnp.full_like(msg_ref[...], identity)
    outbuf_ref[...] = jnp.where(s == 0, combined, ident)[None]
    outmsg_ref[...] = scratch_ref[...]


def block_acc_shuffle(buffers: jnp.ndarray, msg: jnp.ndarray,
                      acc_idx: jnp.ndarray, fwd_idx: jnp.ndarray,
                      *, op: str = "sum", interpret=None):
    """Fused accumulate(t) + capture/drain(t+1) for the reduce family.

    buffers: [R, nslots, bs]; msg: [R, bs] incoming partials;
    acc_idx/fwd_idx: [R] int32 slots.  Per row r, in order:

      1. ``buffers[r, acc_idx[r]] op= msg[r]``   (accumulate, round t)
      2. ``out_msg[r] = buffers[r, fwd_idx[r]]`` (capture, round t+1 --
         sees step 1's result when the slots coincide)
      3. ``buffers[r, fwd_idx[r]] = identity(op, dtype)``  (drain)

    ``op`` is ``"sum"`` (identity 0) or ``"max"`` (identity -inf /
    integer min).  Returns ``(new_buffers, out_msg)``.
    """
    R, nslots, bs = buffers.shape
    identity = op_identity(op, buffers.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, 2),
        in_specs=[
            pl.BlockSpec((1, bs), lambda r, s, ai, fi: (r, 0)),
            # read-only buffer view: the fwd block (pre-update content)
            pl.BlockSpec((1, 1, bs), lambda r, s, ai, fi: (r, fi[r], 0)),
            # aliased buffer: acc block at s=0, fwd block at s=1
            pl.BlockSpec(
                (1, 1, bs),
                lambda r, s, ai, fi: (r, jnp.where(s == 0, ai[r], fi[r]), 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, 1, bs),
                lambda r, s, ai, fi: (r, jnp.where(s == 0, ai[r], fi[r]), 0),
            ),
            pl.BlockSpec((1, bs), lambda r, s, ai, fi: (r, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, bs), buffers.dtype)],
    )
    kern = functools.partial(_acc_shuffle_kernel, op=op, identity=identity)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
            jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        ],
        input_output_aliases={4: 0},   # 2nd buffer operand -> new_buffers
        interpret=_resolve(interpret),
    )(acc_idx.astype(jnp.int32), fwd_idx.astype(jnp.int32),
      msg, buffers, buffers)


# --------------------- fused dequantize+accumulate+requantize (reduce)


def _qacc_shuffle_kernel(acc_ref, fwd_ref, qmsg_ref, smsg_ref, ro_ref,
                         alias_ref, erro_ref, outbuf_ref, outerr_ref,
                         outq_ref, outs_ref, q_scr, s_scr, e_scr, *, nb, qb):
    r = pl.program_id(0)
    s = pl.program_id(1)
    # Same two-step grid as _acc_shuffle_kernel (s=0 accumulate, s=1
    # drain), with the wire format quantized: the incoming message is
    # int8 blocks + per-QBLOCK f32 scales, dequantized on the fly; the
    # captured outgoing partial is requantized for the next hop and its
    # requantization error accumulated into the matching err slot (the
    # per-hop term the error-feedback sum needs -- dropping it is a
    # first-order bias, see optim/compression.py).
    deq = dequant_blocks(
        qmsg_ref[...].reshape(nb, qb), smsg_ref[...].reshape(nb, 1)
    )
    combined = alias_ref[0, 0].reshape(nb, qb) + deq

    @pl.when(s == 0)
    def _():
        same = acc_ref[r] == fwd_ref[r]
        captured = jnp.where(same, combined, ro_ref[0, 0].reshape(nb, qb))
        q, sc = quant_blocks(captured)
        q_scr[...] = q.reshape(1, nb * qb)
        s_scr[...] = sc.reshape(1, nb)
        e_scr[...] = (
            erro_ref[0, 0].reshape(nb, qb) + quant_error(captured, q, sc)
        ).reshape(1, nb * qb)

    outbuf_ref[...] = jnp.where(
        s == 0, combined, jnp.zeros_like(combined)
    ).reshape(1, 1, nb * qb)
    outerr_ref[...] = e_scr[...][None]
    outq_ref[...] = q_scr[...]
    outs_ref[...] = s_scr[...]


def block_qacc_shuffle(buffers: jnp.ndarray, err: jnp.ndarray,
                       qmsg: jnp.ndarray, smsg: jnp.ndarray,
                       acc_idx: jnp.ndarray, fwd_idx: jnp.ndarray,
                       *, interpret=None):
    """Fused dequantize+accumulate(t) + requantize/capture/drain(t+1).

    The quantized-wire variant of :func:`block_acc_shuffle` (sum only).
    buffers/err: [R, nslots, bs] f32 partial sums and their accumulated
    requantization errors; qmsg: [R, bs] int8 incoming payload; smsg:
    [R, nb] f32 per-QBLOCK scales (bs == nb * qb).  Per row r, in order:

      1. ``buffers[r, acc_idx[r]] += dequant(qmsg[r], smsg[r])``
      2. capture ``buffers[r, fwd_idx[r]]`` (sees step 1 when the slots
         coincide), requantize it to ``(out_q[r], out_s[r])``
      3. ``err[r, fwd_idx[r]] += captured - dequant(out_q[r], out_s[r])``
      4. drain ``buffers[r, fwd_idx[r]]`` to zero

    Returns ``(new_buffers, new_err, out_q, out_s)``.  Quantization math
    is :mod:`repro.kernels.quant_ops` (bit-identical to the jnp oracle).
    On TPU the in-kernel (1, bs) -> (nb, qb) relayouts want qb to be a
    multiple of 128 lanes; the default QBLOCK=256 satisfies this.
    """
    R, nslots, bs = buffers.shape
    nb = smsg.shape[1]
    assert bs % nb == 0, (bs, nb)
    qb = bs // nb
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, 2),
        in_specs=[
            pl.BlockSpec((1, bs), lambda r, s, ai, fi: (r, 0)),
            pl.BlockSpec((1, nb), lambda r, s, ai, fi: (r, 0)),
            # read-only buffer view: the fwd block (pre-update content)
            pl.BlockSpec((1, 1, bs), lambda r, s, ai, fi: (r, fi[r], 0)),
            # aliased buffer: acc block at s=0, fwd block at s=1
            pl.BlockSpec(
                (1, 1, bs),
                lambda r, s, ai, fi: (r, jnp.where(s == 0, ai[r], fi[r]), 0),
            ),
            # aliased err buffer: always the fwd block
            pl.BlockSpec((1, 1, bs), lambda r, s, ai, fi: (r, fi[r], 0)),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, 1, bs),
                lambda r, s, ai, fi: (r, jnp.where(s == 0, ai[r], fi[r]), 0),
            ),
            pl.BlockSpec((1, 1, bs), lambda r, s, ai, fi: (r, fi[r], 0)),
            pl.BlockSpec((1, bs), lambda r, s, ai, fi: (r, 0)),
            pl.BlockSpec((1, nb), lambda r, s, ai, fi: (r, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, bs), jnp.int8),
            pltpu.VMEM((1, nb), jnp.float32),
            pltpu.VMEM((1, bs), jnp.float32),
        ],
    )
    kern = functools.partial(_qacc_shuffle_kernel, nb=nb, qb=qb)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), jnp.float32),
            jax.ShapeDtypeStruct((R, nslots, bs), jnp.float32),
            jax.ShapeDtypeStruct((R, bs), jnp.int8),
            jax.ShapeDtypeStruct((R, nb), jnp.float32),
        ],
        # operands counted including the 2 prefetch scalars:
        # 5 = 2nd buffer operand -> new_buffers, 6 = err -> new_err
        input_output_aliases={5: 0, 6: 1},
        interpret=_resolve(interpret),
    )(acc_idx.astype(jnp.int32), fwd_idx.astype(jnp.int32),
      qmsg, smsg, buffers, buffers, err)
