"""Schedule-driven block data-plane Pallas kernels (the per-round hot path).

Every collective in the family runs the same per-round inner step on its
block buffers (paper Algorithms 1-2 and the reversed reduction of
arXiv:2407.18004):

  * broadcast family -- ``pack`` one block per row into the outgoing
    message, exchange, ``unpack`` the incoming message into one slot per
    row;
  * reduce family -- capture the forwarded partial, drain its slot to
    the op identity, exchange, ``accumulate`` the incoming partial.

The block *selection* is the schedule: per-round int32 index vectors
known before the kernel runs but data-dependent per rank / per root row.
``PrefetchScalarGridSpec`` passes them as scalar-prefetch arguments so
every BlockSpec index_map can pick which HBM block to DMA into VMEM --
the pack/unpack becomes pure DMA scheduling with zero real compute,
exactly the paper's "packing ... bounded by the total size of all
buffers" requirement.

Two *fused* kernels cover the steady state with one ``pallas_call`` per
round instead of two:

  * :func:`block_shuffle` -- unpack round t's received message, then
    pack round t+1's outgoing block from the *updated* buffer (the
    pipeline case "forward next what you just received" falls out of the
    in-kernel write-then-select ordering);
  * :func:`block_acc_shuffle` -- accumulate round t's incoming partial
    (sum/max with dtype identities), then capture round t+1's forwarded
    partial and drain its slot to the identity
    (capture-drain-accumulate, see docs/collectives.md).

All kernels run under ``interpret=True`` on CPU CI bit-exactly against
the jnp reference backend (:mod:`repro.core.roundstep`); on TPU the same
code compiles with the index maps lowered to DMA descriptors.  The
fused kernels pass the buffer twice (one read-only operand, one aliased
to the output) so no in-kernel value ever depends on reading back a
block written earlier in the same grid -- the interpret and compiled
modes cannot diverge.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Single source for combine/identity semantics across kernels, the jnp
# oracles and the collectives (re-exported here for consumers that only
# know the kernel module).
from .quant_ops import dequant_blocks, quant_blocks, quant_error
from .reduce_ops import op_combine, op_identity


def default_interpret() -> bool:
    """Auto-detected interpret mode: compiled on TPU, interpreted elsewhere."""
    return jax.default_backend() != "tpu"


def _resolve(interpret):
    return default_interpret() if interpret is None else interpret


# ----------------------------------------------------------- index maps
#
# Every BlockSpec index map is a named module-level function so the
# static race detector (repro.analysis.kernelaudit) can evaluate the
# SAME map objects the pallas_call was built with over the whole grid.
# 1-D kernels get (r, *prefetch_refs); the two-step accumulate/drain
# kernels get (r, s, *prefetch_refs) with s the sequential sub-round.


def _row_map1(r, idx_ref):
    """[R, bs] row block of the 1-prefetch 1-D kernels (pack out)."""
    return (r, 0)


def _slot_map1(r, idx_ref):
    """Prefetched-slot block of the 1-prefetch 1-D kernels."""
    return (r, idx_ref[r], 0)


def _row_map2(r, ri, si):
    """[R, bs] row block of the 2-prefetch 1-D shuffle kernel."""
    return (r, 0)


def _send_map(r, ri, si):
    """Read-only send-slot block of the shuffle kernel (pre-update)."""
    return (r, si[r], 0)


def _recv_map(r, ri, si):
    """Recv-slot block of the shuffle kernel (aliased, overwritten)."""
    return (r, ri[r], 0)


def _row_map_rs(r, s, ai, fi):
    """[R, bs] row block of the two-step accumulate/drain kernels."""
    return (r, 0)


def _fwd_map(r, s, ai, fi):
    """Fwd-slot block of the accumulate/drain kernels (captured and, in
    the qacc error path, read-modify-written)."""
    return (r, fi[r], 0)


def _step_map(r, s, ai, fi):
    """Aliased buffer block of the accumulate/drain kernels: the acc
    slot at s == 0, the fwd slot at s == 1 (the drain)."""
    return (r, jnp.where(s == 0, ai[r], fi[r]), 0)


# Pallas input_output_aliases, operand-indexed INCLUDING the scalar
# prefetch arguments; module-level so the audit reads the exact dicts
# the calls pass.
UNPACK_ALIASES = {2: 0}      # buffers (3rd operand) -> output
SHUFFLE_ALIASES = {4: 0}     # 2nd buffer operand -> new_buffers
ACC_ALIASES = {4: 0}         # 2nd buffer operand -> new_buffers
QACC_ALIASES = {5: 0, 6: 1}  # 2nd buffer operand -> new_buffers, err -> new_err
SHUFFLE_STAGED_ALIASES = {4: 0}  # buffers operand -> new_buffers
ACC_STAGED_ALIASES = {4: 0}      # buffers operand -> new_buffers


# ------------------------------------------------------------------- pack


def _pack_kernel(idx_ref, buf_ref, out_ref):
    # the interesting work happened in the index_map DMA; just copy VMEM->VMEM
    del idx_ref
    out_ref[...] = buf_ref[0]


def block_pack(buffers: jnp.ndarray, idx: jnp.ndarray, *, interpret=None):
    """buffers: [R, nslots, bs]; idx: [R] int32 slot per row -> [R, bs].

    Row r of the output is buffers[r, idx[r]]; the slot choice is the
    send schedule column for the round.
    """
    R, nslots, bs = buffers.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, 1, bs), _slot_map1),
        ],
        out_specs=pl.BlockSpec((1, bs), _row_map1),
    )
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        interpret=_resolve(interpret),
    )(idx.astype(jnp.int32), buffers)


# ----------------------------------------------------------------- unpack


def _unpack_kernel(idx_ref, msg_ref, buf_ref, out_ref):
    del idx_ref, buf_ref  # aliased with the output; untouched slots keep contents
    out_ref[0] = msg_ref[...]


def block_unpack(buffers: jnp.ndarray, msg: jnp.ndarray, idx: jnp.ndarray,
                 *, interpret=None):
    """Scatter msg rows into per-row slots: buffers[r, idx[r]] = msg[r].

    Implemented with an input-output alias so untouched slots keep their
    contents (the receive schedule only writes one slot per round).
    """
    R, nslots, bs = buffers.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, bs), _row_map1),
            pl.BlockSpec((1, 1, bs), _slot_map1),
        ],
        out_specs=pl.BlockSpec((1, 1, bs), _slot_map1),
    )
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
        input_output_aliases=UNPACK_ALIASES,
        interpret=_resolve(interpret),
    )(idx.astype(jnp.int32), msg, buffers)


# ------------------------------------------- fused unpack+pack (broadcast)


def _shuffle_kernel(recv_ref, send_ref, msg_ref, ro_ref, alias_ref,
                    outbuf_ref, outmsg_ref):
    r = pl.program_id(0)
    del alias_ref  # aliased with outbuf; untouched slots keep contents
    # unpack: the received message lands in this row's recv slot
    outbuf_ref[...] = msg_ref[...][None]
    # pack from the UPDATED buffer: when the next send slot is the slot
    # just written (the broadcast pipeline "forward what you received"),
    # the outgoing block is the message itself; otherwise it is the
    # DMA-selected old block.  No read-back of a freshly written block.
    same = recv_ref[r] == send_ref[r]
    outmsg_ref[...] = jnp.where(same, msg_ref[...], ro_ref[0, 0])


def block_shuffle(buffers: jnp.ndarray, msg: jnp.ndarray,
                  recv_idx: jnp.ndarray, send_idx: jnp.ndarray,
                  *, interpret=None):
    """Fused unpack(t) + pack(t+1) for the broadcast family.

    buffers: [R, nslots, bs]; msg: [R, bs] received this round;
    recv_idx/send_idx: [R] int32 slots.  Returns ``(new_buffers,
    out_msg)`` where ``new_buffers[r, recv_idx[r]] = msg[r]`` and
    ``out_msg[r] = new_buffers[r, send_idx[r]]`` (i.e. the pack sees the
    unpack's write -- the round-t+1 send of a round-t delivery).
    """
    R, nslots, bs = buffers.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, bs), _row_map2),
            # read-only buffer view: the send block (pre-update content)
            pl.BlockSpec((1, 1, bs), _send_map),
            # aliased buffer: the recv block (overwritten by the kernel)
            pl.BlockSpec((1, 1, bs), _recv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs), _recv_map),
            pl.BlockSpec((1, bs), _row_map2),
        ],
    )
    return pl.pallas_call(
        _shuffle_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
            jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        ],
        input_output_aliases=SHUFFLE_ALIASES,
        interpret=_resolve(interpret),
    )(recv_idx.astype(jnp.int32), send_idx.astype(jnp.int32),
      msg, buffers, buffers)


# ---------------------------- staged shuffle (overlapped executor mode)


def _shuffle_staged_kernel(recv_ref, send_ref, msg_ref, pre_ref, alias_ref,
                           outbuf_ref, outmsg_ref):
    r = pl.program_id(0)
    del alias_ref  # aliased with outbuf; untouched slots keep contents
    # unpack: the received message lands in this row's recv slot
    outbuf_ref[...] = msg_ref[...][None]
    # the round-t+1 send block was packed from the PRE-update buffer
    # (``pre``) before the exchange completed; the unpack only changed
    # the recv slot, so the staged block is stale exactly when the next
    # send slot IS the recv slot -- patch that one case with the message.
    same = recv_ref[r] == send_ref[r]
    outmsg_ref[...] = jnp.where(same, msg_ref[...], pre_ref[...])


def block_shuffle_staged(buffers: jnp.ndarray, msg: jnp.ndarray,
                         pre: jnp.ndarray, recv_idx: jnp.ndarray,
                         send_idx: jnp.ndarray, *, interpret=None):
    """Overlap-staged variant of :func:`block_shuffle`.

    ``pre`` [R, bs] is round t+1's send block packed from the buffer
    *before* round t's delivery landed, so it can be computed while the
    round-t exchange is still in flight.  The kernel writes ``msg`` into
    the recv slots and selects the outgoing message as ``msg`` where
    ``recv_idx == send_idx`` (the pipeline case -- the only slot the
    unpack changed) and ``pre`` everywhere else.  Bit-exact vs
    ``block_shuffle(buffers, msg, recv_idx, send_idx)`` whenever the
    schedule writes each slot at most once (the write-once invariant the
    static auditor proves).  Returns ``(new_buffers, out_msg)``.
    """
    R, nslots, bs = buffers.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, bs), _row_map2),
            pl.BlockSpec((1, bs), _row_map2),
            # aliased buffer: the recv block (overwritten by the kernel)
            pl.BlockSpec((1, 1, bs), _recv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs), _recv_map),
            pl.BlockSpec((1, bs), _row_map2),
        ],
    )
    return pl.pallas_call(
        _shuffle_staged_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
            jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        ],
        input_output_aliases=SHUFFLE_STAGED_ALIASES,
        interpret=_resolve(interpret),
    )(recv_idx.astype(jnp.int32), send_idx.astype(jnp.int32),
      msg, pre, buffers)


# ------------------------------------- fused accumulate+capture (reduce)


def _acc_shuffle_kernel(acc_ref, fwd_ref, msg_ref, ro_ref, alias_ref,
                        outbuf_ref, outmsg_ref, scratch_ref, *, op, identity):
    r = pl.program_id(0)
    s = pl.program_id(1)
    # s == 0: accumulate the incoming partial into the acc slot.
    # s == 1: drain the (next round's) fwd slot to the identity.
    # The captured outgoing partial is staged through VMEM scratch at
    # s == 0, computed from pre-update values only (combined when the
    # fwd slot IS the acc slot, the old fwd block otherwise) -- never by
    # reading back a block written earlier in the grid, so interpret and
    # compiled modes agree bit-for-bit.
    combined = op_combine(op)(alias_ref[0, 0], msg_ref[...])

    @pl.when(s == 0)
    def _():
        same = acc_ref[r] == fwd_ref[r]
        scratch_ref[...] = jnp.where(same, combined, ro_ref[0, 0])

    ident = jnp.full_like(msg_ref[...], identity)
    outbuf_ref[...] = jnp.where(s == 0, combined, ident)[None]
    outmsg_ref[...] = scratch_ref[...]


def block_acc_shuffle(buffers: jnp.ndarray, msg: jnp.ndarray,
                      acc_idx: jnp.ndarray, fwd_idx: jnp.ndarray,
                      *, op: str = "sum", interpret=None):
    """Fused accumulate(t) + capture/drain(t+1) for the reduce family.

    buffers: [R, nslots, bs]; msg: [R, bs] incoming partials;
    acc_idx/fwd_idx: [R] int32 slots.  Per row r, in order:

      1. ``buffers[r, acc_idx[r]] op= msg[r]``   (accumulate, round t)
      2. ``out_msg[r] = buffers[r, fwd_idx[r]]`` (capture, round t+1 --
         sees step 1's result when the slots coincide)
      3. ``buffers[r, fwd_idx[r]] = identity(op, dtype)``  (drain)

    ``op`` is ``"sum"`` (identity 0) or ``"max"`` (identity -inf /
    integer min).  Returns ``(new_buffers, out_msg)``.
    """
    R, nslots, bs = buffers.shape
    identity = op_identity(op, buffers.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, 2),
        in_specs=[
            pl.BlockSpec((1, bs), _row_map_rs),
            # read-only buffer view: the fwd block (pre-update content)
            pl.BlockSpec((1, 1, bs), _fwd_map),
            # aliased buffer: acc block at s=0, fwd block at s=1
            pl.BlockSpec((1, 1, bs), _step_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs), _step_map),
            pl.BlockSpec((1, bs), _row_map_rs),
        ],
        scratch_shapes=[pltpu.VMEM((1, bs), buffers.dtype)],
    )
    kern = functools.partial(_acc_shuffle_kernel, op=op, identity=identity)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
            jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        ],
        input_output_aliases=ACC_ALIASES,
        interpret=_resolve(interpret),
    )(acc_idx.astype(jnp.int32), fwd_idx.astype(jnp.int32),
      msg, buffers, buffers)


# ------------------- staged accumulate+capture (overlapped reduce mode)


def _acc_shuffle_staged_kernel(acc_ref, fwd_ref, msg_ref, pre_ref, alias_ref,
                               outbuf_ref, outmsg_ref, scratch_ref,
                               *, op, identity):
    r = pl.program_id(0)
    s = pl.program_id(1)
    # Same two-step grid as _acc_shuffle_kernel (s=0 accumulate, s=1
    # drain), but the captured outgoing partial for the non-coincident
    # case comes from ``pre`` -- the fwd block packed from the
    # PRE-update buffer while the exchange was in flight -- instead of a
    # second read-only buffer view.  The accumulate only changed the acc
    # slot, so ``pre`` is stale exactly when fwd == acc; patch that case
    # with the freshly combined value.
    combined = op_combine(op)(alias_ref[0, 0], msg_ref[...])

    @pl.when(s == 0)
    def _():
        same = acc_ref[r] == fwd_ref[r]
        scratch_ref[...] = jnp.where(same, combined, pre_ref[...])

    ident = jnp.full_like(msg_ref[...], identity)
    outbuf_ref[...] = jnp.where(s == 0, combined, ident)[None]
    outmsg_ref[...] = scratch_ref[...]


def block_acc_shuffle_staged(buffers: jnp.ndarray, msg: jnp.ndarray,
                             pre: jnp.ndarray, acc_idx: jnp.ndarray,
                             fwd_idx: jnp.ndarray, *, op: str = "sum",
                             interpret=None):
    """Overlap-staged variant of :func:`block_acc_shuffle`.

    ``pre`` [R, bs] is round t+1's fwd block packed from the buffer
    *before* round t's partial was accumulated, so it can be computed
    while the round-t exchange is still in flight.  Per row r:

      1. ``buffers[r, acc_idx[r]] op= msg[r]``   (accumulate, round t)
      2. ``out_msg[r]`` = the combined value where ``fwd_idx == acc_idx``
         (the only slot step 1 changed), ``pre[r]`` otherwise
      3. ``buffers[r, fwd_idx[r]] = identity(op, dtype)``  (drain)

    Bit-exact vs ``block_acc_shuffle(buffers, msg, acc_idx, fwd_idx)``:
    the sequential capture also reads pre-accumulate content everywhere
    except the coincident slot.  Returns ``(new_buffers, out_msg)``.
    """
    R, nslots, bs = buffers.shape
    identity = op_identity(op, buffers.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, 2),
        in_specs=[
            pl.BlockSpec((1, bs), _row_map_rs),
            pl.BlockSpec((1, bs), _row_map_rs),
            # aliased buffer: acc block at s=0, fwd block at s=1
            pl.BlockSpec((1, 1, bs), _step_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs), _step_map),
            pl.BlockSpec((1, bs), _row_map_rs),
        ],
        scratch_shapes=[pltpu.VMEM((1, bs), buffers.dtype)],
    )
    kern = functools.partial(
        _acc_shuffle_staged_kernel, op=op, identity=identity)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), buffers.dtype),
            jax.ShapeDtypeStruct((R, bs), buffers.dtype),
        ],
        input_output_aliases=ACC_STAGED_ALIASES,
        interpret=_resolve(interpret),
    )(acc_idx.astype(jnp.int32), fwd_idx.astype(jnp.int32),
      msg, pre, buffers)


# --------------------- fused dequantize+accumulate+requantize (reduce)


def _qacc_shuffle_kernel(acc_ref, fwd_ref, qmsg_ref, smsg_ref, ro_ref,
                         alias_ref, erro_ref, outbuf_ref, outerr_ref,
                         outq_ref, outs_ref, q_scr, s_scr, e_scr, *, nb, qb):
    r = pl.program_id(0)
    s = pl.program_id(1)
    # Same two-step grid as _acc_shuffle_kernel (s=0 accumulate, s=1
    # drain), with the wire format quantized: the incoming message is
    # int8 blocks + per-QBLOCK f32 scales, dequantized on the fly; the
    # captured outgoing partial is requantized for the next hop and its
    # requantization error accumulated into the matching err slot (the
    # per-hop term the error-feedback sum needs -- dropping it is a
    # first-order bias, see optim/compression.py).
    deq = dequant_blocks(
        qmsg_ref[...].reshape(nb, qb), smsg_ref[...].reshape(nb, 1)
    )
    combined = alias_ref[0, 0].reshape(nb, qb) + deq

    @pl.when(s == 0)
    def _():
        same = acc_ref[r] == fwd_ref[r]
        captured = jnp.where(same, combined, ro_ref[0, 0].reshape(nb, qb))
        q, sc = quant_blocks(captured)
        q_scr[...] = q.reshape(1, nb * qb)
        s_scr[...] = sc.reshape(1, nb)
        e_scr[...] = (
            erro_ref[0, 0].reshape(nb, qb) + quant_error(captured, q, sc)
        ).reshape(1, nb * qb)

    outbuf_ref[...] = jnp.where(
        s == 0, combined, jnp.zeros_like(combined)
    ).reshape(1, 1, nb * qb)
    outerr_ref[...] = e_scr[...][None]
    outq_ref[...] = q_scr[...]
    outs_ref[...] = s_scr[...]


def block_qacc_shuffle(buffers: jnp.ndarray, err: jnp.ndarray,
                       qmsg: jnp.ndarray, smsg: jnp.ndarray,
                       acc_idx: jnp.ndarray, fwd_idx: jnp.ndarray,
                       *, interpret=None):
    """Fused dequantize+accumulate(t) + requantize/capture/drain(t+1).

    The quantized-wire variant of :func:`block_acc_shuffle` (sum only).
    buffers/err: [R, nslots, bs] f32 partial sums and their accumulated
    requantization errors; qmsg: [R, bs] int8 incoming payload; smsg:
    [R, nb] f32 per-QBLOCK scales (bs == nb * qb).  Per row r, in order:

      1. ``buffers[r, acc_idx[r]] += dequant(qmsg[r], smsg[r])``
      2. capture ``buffers[r, fwd_idx[r]]`` (sees step 1 when the slots
         coincide), requantize it to ``(out_q[r], out_s[r])``
      3. ``err[r, fwd_idx[r]] += captured - dequant(out_q[r], out_s[r])``
      4. drain ``buffers[r, fwd_idx[r]]`` to zero

    Returns ``(new_buffers, new_err, out_q, out_s)``.  Quantization math
    is :mod:`repro.kernels.quant_ops` (bit-identical to the jnp oracle).
    On TPU the in-kernel (1, bs) -> (nb, qb) relayouts want qb to be a
    multiple of 128 lanes; the default QBLOCK=256 satisfies this.
    """
    R, nslots, bs = buffers.shape
    nb = smsg.shape[1]
    assert bs % nb == 0, (bs, nb)
    qb = bs // nb
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, 2),
        in_specs=[
            pl.BlockSpec((1, bs), _row_map_rs),
            pl.BlockSpec((1, nb), _row_map_rs),
            # read-only buffer view: the fwd block (pre-update content)
            pl.BlockSpec((1, 1, bs), _fwd_map),
            # aliased buffer: acc block at s=0, fwd block at s=1
            pl.BlockSpec((1, 1, bs), _step_map),
            # aliased err buffer: always the fwd block
            pl.BlockSpec((1, 1, bs), _fwd_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs), _step_map),
            pl.BlockSpec((1, 1, bs), _fwd_map),
            pl.BlockSpec((1, bs), _row_map_rs),
            pl.BlockSpec((1, nb), _row_map_rs),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, bs), jnp.int8),
            pltpu.VMEM((1, nb), jnp.float32),
            pltpu.VMEM((1, bs), jnp.float32),
        ],
    )
    kern = functools.partial(_qacc_shuffle_kernel, nb=nb, qb=qb)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, nslots, bs), jnp.float32),
            jax.ShapeDtypeStruct((R, nslots, bs), jnp.float32),
            jax.ShapeDtypeStruct((R, bs), jnp.int8),
            jax.ShapeDtypeStruct((R, nb), jnp.float32),
        ],
        # operands counted including the 2 prefetch scalars:
        # 5 = 2nd buffer operand -> new_buffers, 6 = err -> new_err
        input_output_aliases=QACC_ALIASES,
        interpret=_resolve(interpret),
    )(acc_idx.astype(jnp.int32), fwd_idx.astype(jnp.int32),
      qmsg, smsg, buffers, buffers, err)


# ------------------------------------------------------- audit registry
#
# Machine-checkable metadata for repro.analysis.kernelaudit: for every
# kernel, the grid, the operand layout (the SAME index-map function
# objects and alias dicts the pallas_call above was built with), which
# logical storage each operand addresses, and at which grid points an
# input block's value is actually consumed ("live").  The detector
# replays the grid in Pallas' sequential lexicographic order and flags
# (a) two grid points writing one block of one storage outside the
# declared drain dimension, (b) a live input read of a block a strictly
# earlier grid point wrote (the interpret==compiled divergence hazard),
# and (c) alias pairs whose index maps disagree anywhere on the grid.


@dataclass(frozen=True)
class OperandAudit:
    """One pallas operand as the race detector sees it.

    ``storage`` names the logical HBM buffer the index map addresses --
    operands passed the same array (the read-only + aliased buffer
    trick) share a storage name, as does an output aliased onto an
    input.  ``live`` is None for "consumed at every grid point" or a
    predicate over the grid tuple; a fetched-but-discarded block (the
    drain sub-round's alias read) is dead and cannot race.
    """

    name: str
    storage: str
    index_map: Callable
    block: Tuple[int, ...]
    live: Optional[Callable] = None


@dataclass(frozen=True)
class KernelAudit:
    """Static audit description of one schedule-driven kernel."""

    name: str
    grid: Tuple[int, ...]
    num_scalar_prefetch: int
    scalar_names: Tuple[str, ...]
    inputs: Tuple[OperandAudit, ...]
    outputs: Tuple[OperandAudit, ...]
    #: pallas input_output_aliases (operand-indexed incl. prefetch)
    aliases: Tuple[Tuple[int, int], ...]
    #: grid dims along which one block may be rewritten sequentially
    #: (the two-step accumulate-then-drain sub-round); () elsewhere
    drain_dims: Tuple[int, ...]
    #: buffer dtype -> expected output dtypes (the no-silent-widening
    #: contract of the sum/max/qacc paths)
    out_dtypes: Callable


KERNEL_NAMES = ("block_pack", "block_unpack", "block_shuffle",
                "block_shuffle_staged", "block_acc_shuffle",
                "block_acc_shuffle_staged", "block_qacc_shuffle")


def _live_acc_step(g) -> bool:
    """Accumulate/drain kernels consume their inputs only in the s == 0
    sub-round; every s == 1 fetch is staged-through or discarded."""
    return g[1] == 0


def kernel_audit_spec(name: str, *, R: int, nslots: int, bs: int,
                      nb: int = 1) -> KernelAudit:
    """The :class:`KernelAudit` for kernel ``name`` at concrete sizes.

    Single-sourced with the real calls: the returned records reference
    the very index-map functions and alias dicts the ``pallas_call``\\ s
    in this module pass, so auditing them audits the shipped kernels.
    """
    f32, i8 = jnp.float32, jnp.int8
    if name == "block_pack":
        return KernelAudit(
            name=name, grid=(R,), num_scalar_prefetch=1,
            scalar_names=("idx",),
            inputs=(OperandAudit("buffers", "buf", _slot_map1, (1, 1, bs)),),
            outputs=(OperandAudit("out", "msg", _row_map1, (1, bs)),),
            aliases=(), drain_dims=(),
            out_dtypes=lambda dt: (dt,))
    if name == "block_unpack":
        return KernelAudit(
            name=name, grid=(R,), num_scalar_prefetch=1,
            scalar_names=("idx",),
            inputs=(
                OperandAudit("msg", "msg", _row_map1, (1, bs)),
                # aliased with the output; its fetched block is never
                # consumed (the kernel dels the ref)
                OperandAudit("buffers", "buf", _slot_map1, (1, 1, bs),
                             live=lambda g: False),
            ),
            outputs=(OperandAudit("out", "buf", _slot_map1, (1, 1, bs)),),
            aliases=tuple(sorted(UNPACK_ALIASES.items())), drain_dims=(),
            out_dtypes=lambda dt: (dt,))
    if name == "block_shuffle":
        return KernelAudit(
            name=name, grid=(R,), num_scalar_prefetch=2,
            scalar_names=("recv_idx", "send_idx"),
            inputs=(
                OperandAudit("msg", "msg", _row_map2, (1, bs)),
                OperandAudit("ro", "buf", _send_map, (1, 1, bs)),
                OperandAudit("alias", "buf", _recv_map, (1, 1, bs),
                             live=lambda g: False),
            ),
            outputs=(
                OperandAudit("outbuf", "buf", _recv_map, (1, 1, bs)),
                OperandAudit("outmsg", "outmsg", _row_map2, (1, bs)),
            ),
            aliases=tuple(sorted(SHUFFLE_ALIASES.items())), drain_dims=(),
            out_dtypes=lambda dt: (dt, dt))
    if name == "block_shuffle_staged":
        return KernelAudit(
            name=name, grid=(R,), num_scalar_prefetch=2,
            scalar_names=("recv_idx", "send_idx"),
            inputs=(
                OperandAudit("msg", "msg", _row_map2, (1, bs)),
                OperandAudit("pre", "pre", _row_map2, (1, bs)),
                OperandAudit("alias", "buf", _recv_map, (1, 1, bs),
                             live=lambda g: False),
            ),
            outputs=(
                OperandAudit("outbuf", "buf", _recv_map, (1, 1, bs)),
                OperandAudit("outmsg", "outmsg", _row_map2, (1, bs)),
            ),
            aliases=tuple(sorted(SHUFFLE_STAGED_ALIASES.items())),
            drain_dims=(),
            out_dtypes=lambda dt: (dt, dt))
    if name == "block_acc_shuffle":
        return KernelAudit(
            name=name, grid=(R, 2), num_scalar_prefetch=2,
            scalar_names=("acc_idx", "fwd_idx"),
            inputs=(
                OperandAudit("msg", "msg", _row_map_rs, (1, bs),
                             live=_live_acc_step),
                OperandAudit("ro", "buf", _fwd_map, (1, 1, bs),
                             live=_live_acc_step),
                OperandAudit("alias", "buf", _step_map, (1, 1, bs),
                             live=_live_acc_step),
            ),
            outputs=(
                OperandAudit("outbuf", "buf", _step_map, (1, 1, bs)),
                OperandAudit("outmsg", "outmsg", _row_map_rs, (1, bs)),
            ),
            aliases=tuple(sorted(ACC_ALIASES.items())), drain_dims=(1,),
            out_dtypes=lambda dt: (dt, dt))
    if name == "block_acc_shuffle_staged":
        return KernelAudit(
            name=name, grid=(R, 2), num_scalar_prefetch=2,
            scalar_names=("acc_idx", "fwd_idx"),
            inputs=(
                OperandAudit("msg", "msg", _row_map_rs, (1, bs),
                             live=_live_acc_step),
                OperandAudit("pre", "pre", _row_map_rs, (1, bs),
                             live=_live_acc_step),
                OperandAudit("alias", "buf", _step_map, (1, 1, bs),
                             live=_live_acc_step),
            ),
            outputs=(
                OperandAudit("outbuf", "buf", _step_map, (1, 1, bs)),
                OperandAudit("outmsg", "outmsg", _row_map_rs, (1, bs)),
            ),
            aliases=tuple(sorted(ACC_STAGED_ALIASES.items())),
            drain_dims=(1,),
            out_dtypes=lambda dt: (dt, dt))
    if name == "block_qacc_shuffle":
        return KernelAudit(
            name=name, grid=(R, 2), num_scalar_prefetch=2,
            scalar_names=("acc_idx", "fwd_idx"),
            inputs=(
                OperandAudit("qmsg", "qmsg", _row_map_rs, (1, bs),
                             live=_live_acc_step),
                OperandAudit("smsg", "smsg", _row_map_rs, (1, nb),
                             live=_live_acc_step),
                OperandAudit("ro", "buf", _fwd_map, (1, 1, bs),
                             live=_live_acc_step),
                OperandAudit("alias", "buf", _step_map, (1, 1, bs),
                             live=_live_acc_step),
                OperandAudit("erro", "err", _fwd_map, (1, 1, bs),
                             live=_live_acc_step),
            ),
            outputs=(
                OperandAudit("outbuf", "buf", _step_map, (1, 1, bs)),
                OperandAudit("outerr", "err", _fwd_map, (1, 1, bs)),
                OperandAudit("outq", "outq", _row_map_rs, (1, bs)),
                OperandAudit("outs", "outs", _row_map_rs, (1, nb)),
            ),
            aliases=tuple(sorted(QACC_ALIASES.items())), drain_dims=(1,),
            out_dtypes=lambda dt: (f32, f32, i8, f32))
    raise ValueError(f"unknown kernel {name!r} (use one of {KERNEL_NAMES})")
