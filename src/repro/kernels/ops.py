"""jit'd public wrappers around the Pallas kernels.

Models call these through ``use_pallas(...)`` switches; by default the
pure-jnp references are used (they lower everywhere, incl. the 512-device
dry-run), while tests and TPU deployments enable the kernels.
"""

from __future__ import annotations

import logging
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .block_pack import (
    block_acc_shuffle,
    block_acc_shuffle_staged,
    block_pack,
    block_qacc_shuffle,
    block_shuffle,
    block_shuffle_staged,
    block_unpack,
    default_interpret,
)
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan

logger = logging.getLogger(__name__)

_mode_logged = False


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> auto-detect the platform: compiled on TPU, interpret
    elsewhere (so CPU CI still runs every kernel).  Logs the chosen mode
    once per process."""
    global _mode_logged
    if interpret is None:
        interpret = default_interpret()
        if not _mode_logged:
            logger.info(
                "repro.kernels: pallas %s mode (platform=%s)",
                "interpret" if interpret else "compiled",
                jax.default_backend(),
            )
            _mode_logged = True
    return interpret


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def _gqa_flash_attention(q, k, v, *, causal, window, block_q, block_k,
                         interpret):
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    rep = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd_v)
    # query row b*H + kv*rep + r reads kv row (b*H + kv*rep + r) // rep
    of = flash_attention(
        qf, kf, vf, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=interpret, kv_map=rep,
    )
    return of.reshape(B, H, Sq, hd_v).transpose(0, 2, 1, 3)


def gqa_flash_attention(q, k, v, *, causal=True, window=None,
                        block_q=128, block_k=128, interpret=None):
    """GQA wrapper: q [B, Sq, H, hd]; k/v [B, Skv, Hkv, hd(_v)].

    Flattens (batch, head) onto the kernel grid; kv heads are shared via
    the kernel's kv_map index (no repeat materialization).

    ``interpret=None`` auto-detects the platform (compiled on TPU,
    interpret-mode elsewhere), as in :func:`schedule_pack`.
    """
    return _gqa_flash_attention(q, k, v, causal=causal, window=window,
                                block_q=block_q, block_k=block_k,
                                interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def _mamba2_ssd(x, B_, C_, dt, A_log, D, *, chunk, interpret):
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    xf = x.transpose(0, 2, 1, 3).reshape(Bsz * H, S, P)
    Bh = jnp.repeat(B_, rep, axis=2).transpose(0, 2, 1, 3).reshape(Bsz * H, S, N)
    Ch = jnp.repeat(C_, rep, axis=2).transpose(0, 2, 1, 3).reshape(Bsz * H, S, N)
    dtf = dt.transpose(0, 2, 1).reshape(Bsz * H, S)
    alog = jnp.tile(A_log, Bsz)
    d = jnp.tile(D, Bsz)
    yf = ssd_scan(xf, Bh, Ch, dtf, alog, d, chunk=chunk, interpret=interpret)
    return yf.reshape(Bsz, H, S, P).transpose(0, 2, 1, 3)


def mamba2_ssd(x, B_, C_, dt, A_log, D, *, chunk=64, interpret=None):
    """x: [B, S, H, P]; B_/C_: [B, S, G, N]; dt: [B, S, H]; A_log/D: [H].

    ``interpret=None`` auto-detects the platform (compiled on TPU,
    interpret-mode elsewhere), as in :func:`schedule_pack`.
    """
    return _mamba2_ssd(x, B_, C_, dt, A_log, D, chunk=chunk,
                       interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def _schedule_pack(buffers, idx, *, interpret):
    return block_pack(buffers, idx, interpret=interpret)


def schedule_pack(buffers, idx, *, interpret=None):
    """Pack one block per row: ``out[r] = buffers[r, idx[r]]``.

    ``interpret=None`` auto-detects the platform (compiled on TPU,
    interpret-mode elsewhere) and logs the chosen mode once.
    """
    return _schedule_pack(buffers, idx, interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def _schedule_unpack(buffers, msg, idx, *, interpret):
    return block_unpack(buffers, msg, idx, interpret=interpret)


def schedule_unpack(buffers, msg, idx, *, interpret=None):
    """Scatter msg rows into per-row slots: ``buffers[r, idx[r]] = msg[r]``.

    ``interpret=None`` auto-detects the platform, as in
    :func:`schedule_pack`.
    """
    return _schedule_unpack(buffers, msg, idx,
                            interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def _schedule_shuffle(buffers, msg, recv_idx, send_idx, *, interpret):
    return block_shuffle(buffers, msg, recv_idx, send_idx, interpret=interpret)


def schedule_shuffle(buffers, msg, recv_idx, send_idx, *, interpret=None):
    """Fused unpack(t)+pack(t+1) round step for the broadcast family."""
    return _schedule_shuffle(buffers, msg, recv_idx, send_idx,
                             interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def _schedule_shuffle_staged(buffers, msg, pre, recv_idx, send_idx, *,
                             interpret):
    return block_shuffle_staged(buffers, msg, pre, recv_idx, send_idx,
                                interpret=interpret)


def schedule_shuffle_staged(buffers, msg, pre, recv_idx, send_idx, *,
                            interpret=None):
    """Overlap-staged round step: ``pre`` is round t+1's send block
    packed before round t's delivery landed (see block_shuffle_staged)."""
    return _schedule_shuffle_staged(buffers, msg, pre, recv_idx, send_idx,
                                    interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("op", "interpret"))
def _schedule_acc_shuffle(buffers, msg, acc_idx, fwd_idx, *, op, interpret):
    return block_acc_shuffle(buffers, msg, acc_idx, fwd_idx, op=op,
                             interpret=interpret)


def schedule_acc_shuffle(buffers, msg, acc_idx, fwd_idx, *, op="sum",
                         interpret=None):
    """Fused accumulate(t)+capture/drain(t+1) round step (reduce family)."""
    return _schedule_acc_shuffle(buffers, msg, acc_idx, fwd_idx, op=op,
                                 interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("op", "interpret"))
def _schedule_acc_shuffle_staged(buffers, msg, pre, acc_idx, fwd_idx, *, op,
                                 interpret):
    return block_acc_shuffle_staged(buffers, msg, pre, acc_idx, fwd_idx,
                                    op=op, interpret=interpret)


def schedule_acc_shuffle_staged(buffers, msg, pre, acc_idx, fwd_idx, *,
                                op="sum", interpret=None):
    """Overlap-staged reduce round step: ``pre`` is round t+1's fwd block
    packed before round t's partial accumulated (see
    block_acc_shuffle_staged)."""
    return _schedule_acc_shuffle_staged(buffers, msg, pre, acc_idx, fwd_idx,
                                        op=op,
                                        interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def _schedule_qacc_shuffle(buffers, err, qmsg, smsg, acc_idx, fwd_idx, *,
                           interpret):
    return block_qacc_shuffle(buffers, err, qmsg, smsg, acc_idx, fwd_idx,
                              interpret=interpret)


def schedule_qacc_shuffle(buffers, err, qmsg, smsg, acc_idx, fwd_idx, *,
                          interpret=None):
    """Quantized-wire accumulate(t)+requantize/capture/drain(t+1) round
    step (sum reduce with per-hop error capture, see block_qacc_shuffle)."""
    return _schedule_qacc_shuffle(buffers, err, qmsg, smsg, acc_idx, fwd_idx,
                                  interpret=resolve_interpret(interpret))
