"""Shared int8 block-quantization math for the compressed data plane.

One source of truth for the quantize / dequantize / error arithmetic used
by three layers that must agree bit-for-bit:

  * ``optim/compression.py``   -- host-side quantize for the legacy ring;
  * ``kernels/ref.py``         -- the jnp oracle for the fused round-step;
  * ``kernels/block_pack.py``  -- the Pallas kernel body (same jnp ops
    traced inside the kernel, so interpret and compiled agree).

Scheme: per-block symmetric int8.  A [nb, QBLOCK] f32 tile quantizes to
(q int8 [nb, QBLOCK], scale f32 [nb, 1]) with scale = amax/127 floored at
``SCALE_FLOOR``.

Non-finite handling: a NaN/inf entry must not silently poison its block
(the old ``quantize_int8`` let a single inf drive the scale to inf, so
every *other* entry in the block dequantized to 0 or NaN with no signal).
Here the finite entries quantize normally against a scale computed over
finite entries only, and the block's *scale* is set to NaN as a
deterministic per-block nonfinite flag: dequantization yields an all-NaN
block (visible to grad-norm / nonfinite checks downstream), while
``quant_error`` reports exactly 0 for flagged lanes so error feedback is
never poisoned.  No extra wire bytes are spent on the flag.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 256
SCALE_FLOOR = 1e-12
# Explicit f32 reciprocal: XLA strength-reduces division by the
# constant 127 into multiplication by its reciprocal anyway (different
# rounding than true division); writing the multiply in the source
# makes the rounding reproducible by plain NumPy references.
INV127 = np.float32(1.0) / np.float32(127.0)

__all__ = [
    "QBLOCK",
    "SCALE_FLOOR",
    "quant_blocks",
    "dequant_blocks",
    "quant_error",
    "block_nonfinite",
]


def quant_blocks(x2d: jnp.ndarray):
    """Quantize a [nb, qb] f32 tile -> (q int8 [nb, qb], scale f32 [nb, 1]).

    The scale of any block containing a non-finite entry is NaN (the
    per-block nonfinite flag); its finite lanes are still quantized
    against the finite amax so no information is lost on the wire.
    """
    x2d = x2d.astype(jnp.float32)
    finite = jnp.isfinite(x2d)
    xf = jnp.where(finite, x2d, 0.0)
    amax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = jnp.maximum(amax * INV127, SCALE_FLOOR)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    all_finite = jnp.all(finite, axis=1, keepdims=True)
    scale = jnp.where(all_finite, scale, jnp.float32(jnp.nan))
    return q, scale.astype(jnp.float32)


def dequant_blocks(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Dequantize [nb, qb] int8 against [nb, 1] scales -> [nb, qb] f32.

    Flagged (NaN-scale) blocks dequantize to all-NaN deterministically.

    The result passes through an optimization barrier: without it XLA is
    free to contract the dequant multiply into a caller's accumulate add
    (FMA), and whether it does depends on the surrounding graph -- the
    jnp oracle and the interpreted Pallas kernel would then disagree in
    the last bit.  The barrier pins round-after-multiply semantics in
    every backend.
    """
    return jax.lax.optimization_barrier(q.astype(jnp.float32) * scale)


def quant_error(x2d: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray):
    """Elementwise quantization error x - dq, with non-finite lanes zeroed.

    Zeroing keeps error-feedback state finite even when a gradient leaf
    goes NaN/inf for a step -- the flag travels via the NaN scale, not
    via the feedback buffer.
    """
    err = x2d.astype(jnp.float32) - dequant_blocks(q, scale)
    return jnp.where(jnp.isfinite(err), err, 0.0)


def block_nonfinite(scale: jnp.ndarray) -> jnp.ndarray:
    """Per-block nonfinite flag surfaced from a quantized scale vector."""
    return ~jnp.isfinite(scale)
