"""Pallas TPU flash-attention kernel (forward).

Grid: (batch*heads, n_q_blocks, n_kv_blocks); the last grid dim is
sequential on TPU, so the online-softmax running state (m, l, acc) lives
in VMEM scratch across kv steps.  BlockSpecs tile q/k/v into VMEM blocks
of (block_q x head_dim) / (block_k x head_dim) -- MXU-aligned when
block_* are multiples of 128 (pad head_dim outside, see ops.py).

GQA is handled in ops.py by flattening query heads and repeating the kv
head index in the k/v index_map (no data duplication: the same kv block
is DMA'd for each of the `rep` query heads of a group).  Causal +
sliding-window masks are applied in-block; blocks entirely above the
diagonal are skipped with pl.when.

Validated in interpret mode against ref.py (pure-jnp oracle) across
shape/dtype sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .block_pack import _resolve

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, n_kv: int,
               causal: bool, window: Optional[int], seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def compute():
        q = q_ref[0].astype(jnp.float32)                     # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                     # [bk, hd]
        v = v_ref[0].astype(jnp.float32)                     # [bk, hdv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                            # [bq, bk]
        mask = k_pos < seq_kv
        if causal:
            mask = mask & (k_pos <= q_pos)
            if window is not None:
                mask = mask & (q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # [bq, bk]
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip blocks entirely above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,                       # [BH, Sq, hd]
    k: jnp.ndarray,                       # [BH, Skv, hd]  (kv head repeated
    v: jnp.ndarray,                       #                 logically via kv_map)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
    seq_kv: Optional[int] = None,
    kv_map: Optional[int] = None,         # GQA repeat factor (H // Hkv)
):
    """Flash attention over flattened heads via pl.pallas_call.

    kv_map: GQA group size -- query row b reads kv row b // kv_map
    (no repeated-kv materialization; the same kv block is DMA'd for each
    query head of the group).
    """
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    hd_v = v.shape[2]
    seq_kv = Skv if seq_kv is None else seq_kv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    n_q = -(-Sq // block_q)
    n_kv = -(-Skv // block_k)
    if Sq % block_q:
        q = jnp.pad(q, ((0, 0), (0, n_q * block_q - Sq), (0, 0)))
    if Skv % block_k:
        k = jnp.pad(k, ((0, 0), (0, n_kv * block_k - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_kv * block_k - Skv), (0, 0)))
    scale = 1.0 / math.sqrt(hd)
    # GQA: query row b = batch*H + kv*rep + r maps to kv row b // rep
    # (pure grid arithmetic -- index_maps cannot capture traced arrays)
    rep = kv_map if isinstance(kv_map, int) and kv_map > 0 else 1
    kv_index = lambda b, i, j: (b // rep, j, 0)

    kern = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv=n_kv, causal=causal, window=window, seq_kv=seq_kv,
    )
    out = pl.pallas_call(
        kern,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd_v), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd_v), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, n_q * block_q, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # m
            pltpu.VMEM((block_q, 1), jnp.float32),      # l
            pltpu.VMEM((block_q, hd_v), jnp.float32),   # acc
        ],
        interpret=_resolve(interpret),
    )(q, k, v)
    return out[:, :Sq]
