"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None):
    """Naive attention.  q: [BH, Sq, hd]; k/v: [BH, Skv, hd(_v)]."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(q.shape[-1])
    qi = jnp.arange(q.shape[1])[:, None]
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask = mask & (kj <= qi)
        if window is not None:
            mask = mask & (qi - kj < window)
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def block_pack_ref(buffers, idx):
    """buffers: [R, nslots, bs]; idx: [R] int32 -> packed [R, bs]."""
    return jnp.take_along_axis(buffers, idx[:, None, None], axis=1)[:, 0]


def block_unpack_ref(buffers, msg, idx):
    """Scatter msg rows into buffers at per-row slots."""
    return buffers.at[jnp.arange(buffers.shape[0]), idx].set(msg)


def block_shuffle_ref(buffers, msg, recv_idx, send_idx):
    """Fused unpack+pack oracle: write msg at recv slots, then read the
    send slots from the UPDATED buffer (pipeline: a round-t delivery may
    be the round-t+1 send).  Returns (new_buffers, out_msg)."""
    rows = jnp.arange(buffers.shape[0])
    buffers = buffers.at[rows, recv_idx].set(msg, mode="promise_in_bounds")
    out = jnp.take_along_axis(buffers, send_idx[:, None, None], axis=1)[:, 0]
    return buffers, out


def block_acc_shuffle_ref(buffers, msg, acc_idx, fwd_idx, op="sum"):
    """Fused accumulate+capture/drain oracle (capture-drain-accumulate
    order of docs/collectives.md): accumulate msg into the acc slots,
    capture the fwd slots from the updated buffer, then drain the fwd
    slots to the op identity.  Returns (new_buffers, out_msg)."""
    from .reduce_ops import op_combine, op_identity

    combine = op_combine(op)
    rows = jnp.arange(buffers.shape[0])
    cur = jnp.take_along_axis(buffers, acc_idx[:, None, None], axis=1)[:, 0]
    buffers = buffers.at[rows, acc_idx].set(
        combine(cur, msg), mode="promise_in_bounds"
    )
    out = jnp.take_along_axis(buffers, fwd_idx[:, None, None], axis=1)[:, 0]
    ident = op_identity(op, buffers.dtype)
    buffers = buffers.at[rows, fwd_idx].set(
        jnp.full_like(out, ident), mode="promise_in_bounds"
    )
    return buffers, out


def ssd_ref(x, B_, C_, dt, A_log, D):
    """Sequential SSD recurrence oracle.  x: [BH, S, P]; B_/C_: [BH, S, N];
    dt: [BH, S]; A_log/D: scalars per row [BH]."""
    A = -jnp.exp(A_log)                                        # [BH]

    def step(s, inp):
        xt, bt, ct, dtt = inp                                  # [BH,P],[BH,N],[BH,N],[BH]
        a = jnp.exp(dtt * A)
        s = s * a[:, None, None] + dtt[:, None, None] * (
            bt[:, :, None] * xt[:, None, :]
        )
        y = jnp.einsum("bn,bnp->bp", ct, s)
        return s, y

    s0 = jnp.zeros((x.shape[0], B_.shape[-1], x.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
         jnp.moveaxis(B_, 1, 0).astype(jnp.float32),
         jnp.moveaxis(C_, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dt, 1, 0).astype(jnp.float32)),
    )
    y = jnp.moveaxis(ys, 0, 1)
    return y + x.astype(jnp.float32) * D[:, None, None]
