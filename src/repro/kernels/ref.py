"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None):
    """Naive attention.  q: [BH, Sq, hd]; k/v: [BH, Skv, hd(_v)]."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(q.shape[-1])
    qi = jnp.arange(q.shape[1])[:, None]
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask = mask & (kj <= qi)
        if window is not None:
            mask = mask & (qi - kj < window)
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def block_pack_ref(buffers, idx):
    """buffers: [R, nslots, bs]; idx: [R] int32 -> packed [R, bs]."""
    return jnp.take_along_axis(buffers, idx[:, None, None], axis=1)[:, 0]


def block_unpack_ref(buffers, msg, idx):
    """Scatter msg rows into buffers at per-row slots."""
    return buffers.at[jnp.arange(buffers.shape[0]), idx].set(msg)


def block_shuffle_ref(buffers, msg, recv_idx, send_idx):
    """Fused unpack+pack oracle: write msg at recv slots, then read the
    send slots from the UPDATED buffer (pipeline: a round-t delivery may
    be the round-t+1 send).  Returns (new_buffers, out_msg)."""
    rows = jnp.arange(buffers.shape[0])
    buffers = buffers.at[rows, recv_idx].set(msg, mode="promise_in_bounds")
    out = jnp.take_along_axis(buffers, send_idx[:, None, None], axis=1)[:, 0]
    return buffers, out


def block_shuffle_staged_ref(buffers, msg, pre, recv_idx, send_idx):
    """Overlap-staged shuffle oracle: ``pre`` is the round-t+1 block
    packed from the PRE-update buffer (before round t's delivery
    landed).  Write msg at the recv slots; the outgoing message is msg
    where the pipeline case ``send == recv`` holds (the only slot the
    update changed) and ``pre`` everywhere else -- bit-exact vs
    :func:`block_shuffle_ref`.  Returns (new_buffers, out_msg)."""
    rows = jnp.arange(buffers.shape[0])
    buffers = buffers.at[rows, recv_idx].set(msg, mode="promise_in_bounds")
    out = jnp.where((recv_idx == send_idx)[:, None], msg, pre)
    return buffers, out


def block_acc_shuffle_staged_ref(buffers, msg, pre, acc_idx, fwd_idx,
                                 op="sum"):
    """Overlap-staged accumulate+capture/drain oracle: ``pre`` is the
    round-t+1 fwd block packed from the PRE-update buffer.  Accumulate
    msg into the acc slots; the captured output is the freshly combined
    value where ``fwd == acc`` (the clamped same-slot case) and ``pre``
    everywhere else, then the fwd slots drain to the op identity --
    bit-exact vs :func:`block_acc_shuffle_ref`.
    Returns (new_buffers, out_msg)."""
    from .reduce_ops import op_combine, op_identity

    combine = op_combine(op)
    rows = jnp.arange(buffers.shape[0])
    cur = jnp.take_along_axis(buffers, acc_idx[:, None, None], axis=1)[:, 0]
    combined = combine(cur, msg)
    buffers = buffers.at[rows, acc_idx].set(
        combined, mode="promise_in_bounds"
    )
    out = jnp.where((acc_idx == fwd_idx)[:, None], combined, pre)
    ident = op_identity(op, buffers.dtype)
    buffers = buffers.at[rows, fwd_idx].set(
        jnp.full_like(out, ident), mode="promise_in_bounds"
    )
    return buffers, out


def block_acc_shuffle_ref(buffers, msg, acc_idx, fwd_idx, op="sum"):
    """Fused accumulate+capture/drain oracle (capture-drain-accumulate
    order of docs/collectives.md): accumulate msg into the acc slots,
    capture the fwd slots from the updated buffer, then drain the fwd
    slots to the op identity.  Returns (new_buffers, out_msg)."""
    from .reduce_ops import op_combine, op_identity

    combine = op_combine(op)
    rows = jnp.arange(buffers.shape[0])
    cur = jnp.take_along_axis(buffers, acc_idx[:, None, None], axis=1)[:, 0]
    buffers = buffers.at[rows, acc_idx].set(
        combine(cur, msg), mode="promise_in_bounds"
    )
    out = jnp.take_along_axis(buffers, fwd_idx[:, None, None], axis=1)[:, 0]
    ident = op_identity(op, buffers.dtype)
    buffers = buffers.at[rows, fwd_idx].set(
        jnp.full_like(out, ident), mode="promise_in_bounds"
    )
    return buffers, out


def block_qacc_shuffle_ref(buffers, err, qmsg, smsg, acc_idx, fwd_idx):
    """Quantized accumulate+capture/drain oracle (sum only).

    The incoming message is int8 blocks ``qmsg`` [R, bs] with per-QBLOCK
    scales ``smsg`` [R, nb] (bs == nb * qb): dequantize, accumulate into
    the acc slots of the f32 ``buffers`` [R, nslots, bs], capture the fwd
    slots from the updated buffer, quantize the captured partial for the
    wire, record the requantization error into the matching slot of
    ``err`` [R, nslots, bs], then drain the fwd slots to zero.

    Returns (new_buffers, new_err, out_q [R, bs] int8, out_s [R, nb] f32).
    """
    from .quant_ops import dequant_blocks, quant_blocks, quant_error

    R, _, bs = buffers.shape
    nb = smsg.shape[1]
    qb = bs // nb
    rows = jnp.arange(R)

    deq = dequant_blocks(
        qmsg.reshape(R * nb, qb), smsg.reshape(R * nb, 1)
    ).reshape(R, bs)
    cur = jnp.take_along_axis(buffers, acc_idx[:, None, None], axis=1)[:, 0]
    buffers = buffers.at[rows, acc_idx].set(
        cur + deq, mode="promise_in_bounds"
    )

    captured = jnp.take_along_axis(buffers, fwd_idx[:, None, None], axis=1)[:, 0]
    q, s = quant_blocks(captured.reshape(R * nb, qb))
    eps = quant_error(captured.reshape(R * nb, qb), q, s).reshape(R, bs)
    cur_e = jnp.take_along_axis(err, fwd_idx[:, None, None], axis=1)[:, 0]
    err = err.at[rows, fwd_idx].set(cur_e + eps, mode="promise_in_bounds")

    buffers = buffers.at[rows, fwd_idx].set(
        jnp.zeros_like(captured), mode="promise_in_bounds"
    )
    return buffers, err, q.reshape(R, bs), s.reshape(R, nb)


def ssd_ref(x, B_, C_, dt, A_log, D):
    """Sequential SSD recurrence oracle.  x: [BH, S, P]; B_/C_: [BH, S, N];
    dt: [BH, S]; A_log/D: scalars per row [BH]."""
    A = -jnp.exp(A_log)                                        # [BH]

    def step(s, inp):
        xt, bt, ct, dtt = inp                                  # [BH,P],[BH,N],[BH,N],[BH]
        a = jnp.exp(dtt * A)
        s = s * a[:, None, None] + dtt[:, None, None] * (
            bt[:, :, None] * xt[:, None, :]
        )
        y = jnp.einsum("bn,bnp->bp", ct, s)
        return s, y

    s0 = jnp.zeros((x.shape[0], B_.shape[-1], x.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
         jnp.moveaxis(B_, 1, 0).astype(jnp.float32),
         jnp.moveaxis(C_, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dt, 1, 0).astype(jnp.float32)),
    )
    y = jnp.moveaxis(ys, 0, 1)
    return y + x.astype(jnp.float32) * D[:, None, None]
