"""Reduction op registry shared by every data-plane layer.

The kernels' drain identity, the jnp oracle's combine, and the
collectives' identity slot must agree bit-for-bit (the reduce family
re-ships drained slots in capped rounds, so the identity must be
absorbing under the combine).  This module is the single source: all of
:mod:`repro.kernels.block_pack`, :mod:`repro.kernels.ref` and
:mod:`repro.core.collectives` resolve ops here, and every entry point
validates the op name instead of silently defaulting.
"""

from __future__ import annotations

import numpy as np

OPS = ("sum", "+", "max")


def _validate(op: str) -> None:
    if op not in OPS:
        raise ValueError(f"unsupported reduction op {op!r} (use 'sum' or 'max')")


def op_combine(op: str):
    """The binary combine of ``op`` as a jnp-traceable callable."""
    import jax.numpy as jnp

    _validate(op)
    return jnp.add if op in ("sum", "+") else jnp.maximum


def op_identity(op: str, dtype) -> np.ndarray:
    """Scalar identity of ``op`` in ``dtype`` (drained slots hold it):
    0 for sum; -inf / the integer minimum for max."""
    import jax.numpy as jnp

    _validate(op)
    dt = np.dtype(dtype)
    if op in ("sum", "+"):
        return np.zeros((), dt)
    if jnp.issubdtype(dt, jnp.inexact):
        return np.asarray(-np.inf, dt)
    return np.asarray(np.iinfo(dt).min, dt)
