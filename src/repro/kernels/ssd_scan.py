"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch*heads, n_chunks); the chunk axis is sequential on TPU, so
the inter-chunk state S in R^{N x P} lives in VMEM scratch and is carried
across chunks (the recurrence the GPU implementation realizes with a
separate kernel launch + global memory round-trip becomes a VMEM-resident
carry -- the TPU-native adaptation of SSD).

Per chunk of length Q the kernel computes, entirely in VMEM:
  * da = dt * A, cum = cumsum(da) (log-decay),
  * intra-chunk dual form: Y += ((C B^T) .* L) (dt x)  with
    L[i,j] = exp(cum_i - cum_j) for i >= j,
  * inter-chunk: Y += (C S_prev) .* exp(cum),
  * state update: S = exp(cum_Q) S_prev + sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .block_pack import _resolve


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, alog_ref, d_ref, y_ref, s_scr,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)        # [Q, P]
    b = b_ref[0].astype(jnp.float32)        # [Q, N]
    c = c_ref[0].astype(jnp.float32)        # [Q, N]
    dt = dt_ref[0].astype(jnp.float32)      # [Q, 1] (padded lane dim)
    A = -jnp.exp(alog_ref[0].astype(jnp.float32))  # scalar [1]
    D = d_ref[0].astype(jnp.float32)

    da = dt[:, 0] * A                       # [Q]
    cum = jnp.cumsum(da)                    # [Q]
    # intra-chunk dual form
    seg = cum[:, None] - cum[None, :]       # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    L = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    w = cb * L * dt[:, 0][None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]
    # inter-chunk contribution from carried state
    s_prev = s_scr[...]                     # [N, P]
    y += jax.lax.dot_general(c, s_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]
    # state update
    decay_to_end = jnp.exp(cum[-1] - cum) * dt[:, 0]               # [Q]
    s_loc = jax.lax.dot_general(b * decay_to_end[:, None], x,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [N, P]
    s_scr[...] = s_prev * jnp.exp(cum[-1]) + s_loc
    y_ref[0] = (y + x * D).astype(y_ref.dtype)


def ssd_scan(x, B_, C_, dt, A_log, D, *, chunk: int = 64, interpret=None):
    """x: [BH, S, P]; B_/C_: [BH, S, N]; dt: [BH, S]; A_log/D: [BH].

    Returns y: [BH, S, P] = SSD(x) + D*x, matching ref.ssd_ref.
    """
    BH, S, P = x.shape
    N = B_.shape[-1]
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
    dt2 = dt[..., None]                      # [BH, S, 1] lane-padded
    alog2 = A_log[:, None]                   # [BH, 1]
    d2 = D[:, None]

    kern = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc * chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=_resolve(interpret),
    )(x, B_, C_, dt2, alog2, d2)
    return y[:, :S]
