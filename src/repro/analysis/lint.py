"""Repo lint: AST-level conventions the schedule stack relies on.

Four rules, each a proven property of the source tree (no imports of the
linted code -- pure :mod:`ast`, so a syntax-error-free tree is the only
prerequisite):

  * **frozen-plan** -- every dataclass whose name marks it as cached
    static state (``*Plan``, ``*Spec``, ``*Bundle``, ``*Static``,
    ``*Audit``) must be declared ``frozen=True``: plan objects are
    shared process-wide by the engine cache and a mutable one breaks the
    identity contract;
  * **host-plane-jax** -- the host-plane modules (the schedule math that
    must stay importable and runnable with NumPy alone) must not import
    jax at module top level; function-local lazy imports are the
    sanctioned escape hatch;
  * **mutable-default** -- no function parameter defaults to a mutable
    literal (``[]``, ``{}``, ``set()`` ...): defaults are evaluated once
    and shared across calls, a classic aliasing bug;
  * **kernel-interpret** -- public entry points in the kernel modules
    (``src/repro/kernels``) with an ``interpret`` parameter must default
    it to ``None`` (platform auto-detection via ``resolve_interpret``):
    a hardcoded ``interpret=True`` silently runs the kernel in interpret
    mode on real accelerators, a hardcoded ``False`` breaks CPU CI;
  * **api-doc** -- every symbol in ``repro.core.__all__`` appears in
    ``docs/api.md`` (the executable docs assert this at test time; the
    lint proves it statically so ``python -m repro.analysis`` catches a
    missing doc without running pytest).

Host-plane module: stdlib only.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List, Optional, Sequence

from .report import Finding, Report

__all__ = [
    "lint_source",
    "lint_file",
    "lint_api_docs",
    "lint_repo",
    "HOST_PLANE",
    "KERNEL_PLANE",
    "FROZEN_NAME",
]

#: Class-name pattern for "cached static state" dataclasses.
FROZEN_NAME = re.compile(r".*(Plan|Spec|Bundle|Static|Audit)$")

#: Modules (repo-relative) that must stay importable without jax.
HOST_PLANE = (
    "src/repro/core/schedule.py",
    "src/repro/core/engine.py",
    "src/repro/core/verify.py",
    "src/repro/core/costmodel.py",
    "src/repro/core/roundstep.py",
    "src/repro/core/reference.py",
    "src/repro/analysis/__init__.py",
    "src/repro/analysis/__main__.py",
    "src/repro/analysis/report.py",
    "src/repro/analysis/planaudit.py",
    "src/repro/analysis/lint.py",
)

#: Directory (repo-relative prefix) whose public entry points must not
#: force interpret mode.
KERNEL_PLANE = "src/repro/kernels/"

_JAX_ROOTS = ("jax", "jaxlib")


def _find(out: List[Finding], check: str, location: str, message: str) -> None:
    out.append(Finding(pass_name="lint", check=check, location=location,
                       message=message))


def _dataclass_frozen(deco: ast.expr) -> Optional[bool]:
    """frozen= value if ``deco`` is a dataclass decorator, else None."""
    target = deco.func if isinstance(deco, ast.Call) else deco
    name = None
    if isinstance(target, ast.Name):
        name = target.id
    elif isinstance(target, ast.Attribute):
        name = target.attr
    if name != "dataclass":
        return None
    if isinstance(deco, ast.Call):
        for kw in deco.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
    return False


def _is_mutable_default(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set", "bytearray")
            and not node.args and not node.keywords):
        return True
    return False


def _interpret_default(node: ast.FunctionDef) -> Optional[ast.expr]:
    """The default expression of a parameter named ``interpret``, if the
    function has one with a default (positional-or-keyword or kw-only)."""
    args = node.args
    pos = args.posonlyargs + args.args
    # defaults align with the tail of the positional parameter list
    for arg, default in zip(pos[len(pos) - len(args.defaults):],
                            args.defaults):
        if arg.arg == "interpret":
            return default
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if arg.arg == "interpret" and default is not None:
            return default
    return None


def lint_source(source: str, path: str = "<string>",
                host_plane: bool = False,
                kernel_plane: bool = False,
                out: Optional[List[Finding]] = None) -> List[Finding]:
    """Lint one module's source text (the unit the negative tests feed
    corrupted strings to)."""
    out = [] if out is None else out
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        _find(out, "syntax", f"{path}:{e.lineno}", str(e))
        return out

    for node in ast.walk(tree):
        # frozen-plan
        if isinstance(node, ast.ClassDef) and FROZEN_NAME.match(node.name):
            verdicts = [v for v in map(_dataclass_frozen, node.decorator_list)
                        if v is not None]
            if verdicts and not any(verdicts):
                _find(out, "frozen-plan", f"{path}:{node.lineno}",
                      f"dataclass {node.name!r} is cached static state "
                      f"and must be @dataclass(frozen=True)")
        # mutable-default
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defaults = (list(node.args.defaults)
                        + [d for d in node.args.kw_defaults if d is not None])
            for d in defaults:
                if _is_mutable_default(d):
                    _find(out, "mutable-default", f"{path}:{d.lineno}",
                          f"function {node.name!r} has a mutable default "
                          f"argument (evaluated once, shared across calls)")
        # kernel-interpret (public kernel entry points only)
        if (kernel_plane and isinstance(node, ast.FunctionDef)
                and not node.name.startswith("_")):
            d = _interpret_default(node)
            if (d is not None and isinstance(d, ast.Constant)
                    and d.value is not None):
                _find(out, "kernel-interpret", f"{path}:{d.lineno}",
                      f"public kernel entry point {node.name!r} defaults "
                      f"interpret={d.value!r}; default it to None and "
                      f"route through resolve_interpret so real "
                      f"accelerators compile the kernel")
        # host-plane-jax (module top level only: body of Module, plus
        # top-level try/if blocks -- anything outside a function)
    if host_plane:
        for node in _toplevel_statements(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[0] in _JAX_ROOTS:
                        _find(out, "host-plane-jax",
                              f"{path}:{node.lineno}",
                              f"top-level 'import {alias.name}' in a "
                              f"host-plane module (lazy-import inside "
                              f"the function that needs it)")
            elif isinstance(node, ast.ImportFrom):
                root = (node.module or "").split(".")[0]
                if node.level == 0 and root in _JAX_ROOTS:
                    _find(out, "host-plane-jax", f"{path}:{node.lineno}",
                          f"top-level 'from {node.module} import ...' in "
                          f"a host-plane module (lazy-import inside the "
                          f"function that needs it)")
    return out


def _toplevel_statements(tree: ast.Module):
    """Module-level statements, descending into top-level If/Try blocks
    (the TYPE_CHECKING / optional-dep patterns) but not into defs."""
    stack = list(tree.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.If, ast.Try, ast.With)):
            for field in ("body", "orelse", "finalbody", "handlers"):
                for child in getattr(node, field, []):
                    stack.append(child.body[0] if isinstance(
                        child, ast.ExceptHandler) and child.body else child)


def lint_file(path: Path, root: Path,
              out: Optional[List[Finding]] = None) -> List[Finding]:
    out = [] if out is None else out
    rel = path.relative_to(root).as_posix()
    lint_source(path.read_text(), rel, host_plane=rel in HOST_PLANE,
                kernel_plane=rel.startswith(KERNEL_PLANE), out=out)
    return out


def lint_api_docs(root: Path,
                  out: Optional[List[Finding]] = None) -> List[Finding]:
    """Statically prove every ``repro.core.__all__`` symbol is mentioned
    in docs/api.md."""
    out = [] if out is None else out
    init = root / "src/repro/core/__init__.py"
    api = root / "docs/api.md"
    if not api.exists():
        _find(out, "api-doc", "docs/api.md", "missing API reference page")
        return out
    tree = ast.parse(init.read_text(), filename=str(init))
    symbols: Sequence[str] = ()
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "__all__"):
            symbols = [ast.literal_eval(e) for e in node.value.elts]
    if not symbols:
        _find(out, "api-doc", "src/repro/core/__init__.py",
              "could not statically read __all__")
        return out
    doc = api.read_text()
    for sym in symbols:
        if not re.search(rf"\b{re.escape(sym)}\b", doc):
            _find(out, "api-doc", "docs/api.md",
                  f"public symbol repro.core.{sym} is undocumented")
    return out


def lint_repo(root: Optional[Path] = None) -> Report:
    """Lint every Python module under src/repro plus the API-doc rule."""
    root = Path(__file__).resolve().parents[3] if root is None else Path(root)
    findings: List[Finding] = []
    files = sorted((root / "src/repro").rglob("*.py"))
    for path in files:
        lint_file(path, root, findings)
    lint_api_docs(root, findings)
    return Report(findings=tuple(findings), checked=len(files) + 1)
