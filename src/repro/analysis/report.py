"""Finding/Report containers shared by every static-analysis pass.

All three passes (:mod:`repro.analysis.planaudit`,
:mod:`repro.analysis.kernelaudit`, :mod:`repro.analysis.lint`) report
*every* violation they can prove rather than failing fast -- a corrupted
plan usually trips several invariants at once and the full list is what
makes the diagnosis one-look.  A :class:`Report` aggregates the findings
with a count of the items that were actually checked, so "0 findings"
is distinguishable from "0 checks ran" (a vacuous pass is itself a bug;
the adversarial tests assert ``checked > 0``).

Host-plane module: stdlib only, no jax/numpy imports.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

__all__ = ["Finding", "Report", "AnalysisError"]


class AnalysisError(AssertionError):
    """Raised by :meth:`Report.raise_if_failed`; an AssertionError so
    the adversarial tests mirror tests/test_verify_negative.py."""


@dataclass(frozen=True)
class Finding:
    """One proven invariant violation.

    ``pass_name`` is the emitting pass (``"plan"``, ``"kernel"``,
    ``"lint"``, ``"cache"``); ``check`` the stable machine-readable
    check id (the adversarial tests key on it); ``location`` a
    human-oriented anchor (a plan/phase description, ``file:line``, a
    kernel name + grid point); ``message`` the specifics.
    """

    pass_name: str
    check: str
    location: str
    message: str

    def __str__(self) -> str:
        return f"[{self.pass_name}:{self.check}] {self.location}: {self.message}"


@dataclass(frozen=True)
class Report:
    """Aggregated findings of one or more passes."""

    findings: Tuple[Finding, ...] = ()
    checked: int = field(default=0)

    @property
    def ok(self) -> bool:
        return not self.findings

    def __add__(self, other: "Report") -> "Report":
        return Report(findings=self.findings + other.findings,
                      checked=self.checked + other.checked)

    def has(self, check: str) -> bool:
        """True if any finding carries the given check id."""
        return any(f.check == check for f in self.findings)

    def summary(self) -> str:
        head = (f"{len(self.findings)} finding(s) over "
                f"{self.checked} checked item(s)")
        if self.ok:
            return head
        return head + "\n" + "\n".join(f"  {f}" for f in self.findings)

    def raise_if_failed(self) -> "Report":
        """Raise :class:`AnalysisError` listing every finding; returns
        self when clean so call sites can chain."""
        if not self.ok:
            raise AnalysisError(self.summary())
        return self
