"""Static plan auditor: prove per-round safety from the tables alone.

Every plan flavour (device :class:`~repro.core.comm.CollectivePlan` /
:class:`~repro.core.hier.HierPlan`, host
:class:`~repro.core.comm.HostDataPlan` /
:class:`~repro.core.hier.HierHostPlan`) exposes ``statics``: the exact
clamped slot tables and per-round rotations its executor closed over
(:class:`~repro.core.roundstep.PhaseStatic`).  This pass discharges the
data-plane invariants on those tables without running a single round:

  * **round count** equals the closed forms, re-derived independently
    (``n-1+ceil(log2 p)`` per phase, doubled for the composed
    all-reductions, summed per level hierarchically);
  * **rotation consistency**: the skip-column sequence matches the
    forward (or reversed) round plan and every wire rotation is the
    bundle skip of its column (negated mod p for reversed phases);
  * **clamped-slot consistency**: the stored tables are entry-for-entry
    the clamp of the bundle's per-round tables (and immutable, the
    ``writeable=False`` cache contract);
  * **write-once** (no write-write races): a rank's real receive slots
    ``< n-1`` are pairwise distinct across rounds -- every data slot is
    written by exactly one round (slot ``n-1`` may recur: final-phase
    capped re-sends rewrite identical content; slot ``n`` is garbage);
  * **no read-after-write aliasing**: a non-root rank never *sends* a
    slot it has not received in a strictly earlier round (the send
    stream reads only already-written destination slots, Condition 4 in
    clamped form);
  * **exchange consistency** (Conditions 1-2 in clamped form): what
    round t reads on the wire at the sender is exactly what its
    receiver writes -- ``send[t][r] == recv[t][(r+skip)%p]`` forward,
    ``fwd[t][r] == acc[t][(r-skip)%p]`` reversed (root column pinned to
    the identity slot and excluded);
  * **reduction liveness**: the root's forward column is pinned to the
    op identity slot, and on non-roots every accumulated real partial
    is forwarded in a strictly later round (nothing stalls);
  * **overlap equivalence** (double-buffered statics only): a symbolic
    per-rank replay of the staged round loop -- next round's block
    packed from the *pre*-update buffer, the in-flight delivery patched
    by the staged step's bypass -- proves the overlapped executor emits
    the same wire stream and final buffer as the sequential loop, round
    for round, from the tables alone;
  * the **schedule-level** forward + reversed correctness conditions of
    :mod:`repro.core.verify` on the underlying bundle (once per
    ``(p, root)``).

Host-plane module: NumPy only, no jax imports (the audited plans are
built elsewhere and passed in; :func:`audit_kind` builds *tables* for
any p through the same process-wide caches, so auditing the paper's
36x32 topology needs no device mesh).
"""

from __future__ import annotations

from dataclasses import fields, is_dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import engine as _engine
from repro.core.engine import get_bundle
from repro.core.roundstep import (
    PhaseStatic,
    allgather_phase_static,
    broadcast_phase_static,
    reduce_phase_static,
    scatter_phase_static,
)
from repro.core.verify import verify_bundle

from .report import Finding, Report

__all__ = [
    "audit_phase",
    "audit_statics",
    "audit_plan",
    "audit_kind",
    "audit_hier_kind",
    "audit_bundle",
    "audit_cache",
    "statics_for_kind",
    "PLAN_KINDS",
    "HIER_PLAN_KINDS",
    "OVERLAP_KINDS",
]

#: Flat plan kinds the auditor can synthesize statics for (the full
#: collective family of repro.core.comm.KINDS, canonicalized).
PLAN_KINDS = ("broadcast", "allgather", "allgatherv", "reduce_scatter",
              "reduce", "allreduce", "quantized_allreduce")

HIER_PLAN_KINDS = ("broadcast", "reduce", "allreduce", "allgather")


def _find(out: List[Finding], check: str, location: str, message: str,
          pass_name: str = "plan") -> None:
    out.append(Finding(pass_name=pass_name, check=check, location=location,
                       message=message))


def _q(p: int) -> int:
    """ceil(log2 p) re-derived independently of repro.core.schedule."""
    return (int(p) - 1).bit_length()


def _phase_rounds(p: int, n: int) -> int:
    """Closed-form per-phase round count, re-derived independently."""
    return 0 if p <= 1 else n - 1 + _q(p)


#: Kinds whose plans accept ``overlap=True`` (repro.core.comm rejects
#: the variable-count and quantized-wire kinds at plan time).
OVERLAP_KINDS = ("broadcast", "allgather", "reduce_scatter", "reduce",
                 "allreduce")


def statics_for_kind(kind: str, p: int, n: int, root: int = 0,
                     overlap: bool = False) -> Tuple[PhaseStatic, ...]:
    """Synthesize the per-phase statics of a flat collective kind from
    the process-wide caches -- the same builders every plan uses, so
    auditing these audits the tables any plan of that spec would run.
    ``overlap=True`` synthesizes the double-buffered executor's statics
    (only for the kinds that support the overlapped mode)."""
    if kind not in PLAN_KINDS:
        raise ValueError(f"unknown plan kind {kind!r} "
                         f"(use one of {PLAN_KINDS})")
    if overlap and kind not in OVERLAP_KINDS:
        raise ValueError(f"overlap statics are not defined for kind "
                         f"{kind!r} (use one of {OVERLAP_KINDS})")
    if p <= 1:
        return ()
    bundle = get_bundle(p, root)
    if kind == "broadcast":
        return (broadcast_phase_static(bundle, n, overlap=overlap),)
    if kind in ("allgather", "allgatherv"):
        return (allgather_phase_static(bundle, n, overlap=overlap),)
    if kind == "reduce_scatter":
        return (scatter_phase_static(bundle, n, overlap=overlap),)
    if kind == "reduce":
        return (reduce_phase_static(bundle, n, overlap=overlap),)
    return (reduce_phase_static(bundle, n, overlap=overlap),
            broadcast_phase_static(bundle, n, overlap=overlap))


def _expected_phases(kind: str) -> Tuple[str, ...]:
    """Phase-kind sequence a flat plan of ``kind`` must carry."""
    return {
        "broadcast": ("broadcast",),
        "allgather": ("allgather",),
        "allgatherv": ("allgather",),
        "allbroadcast": ("allgather",),
        "reduce_scatter": ("scatter",),
        "reduce": ("reduce",),
        "allreduce": ("reduce", "broadcast"),
        "quantized_allreduce": ("reduce", "broadcast"),
    }[kind]


# ------------------------------------------------- overlap equivalence
#
# The double-buffered executor packs round t+1's block from the
# PRE-update buffer while round t's exchange is in flight, then runs
# the staged step whose bypass patches the one slot round t writes.
# These replays prove, from the tables alone, that the staged loop
# emits the same wire stream and final buffer as the sequential loop:
# slots hold opaque symbols (multisets of symbols in the reversed
# direction), and the round-t delivery is the same symbol in both
# executors -- valid by induction on rounds, since matching wire
# streams through round t imply matching deliveries at round t.

_IDENT = ()  # the op identity: the empty multiset of partials


def _overlap_fwd_replay(recv: np.ndarray, send: np.ndarray, n: int, r: int,
                        out: List[Finding], loc: str) -> None:
    """One rank's forward rounds, sequential vs staged (broadcast /
    allgather layout: n+1 slots, slot n garbage)."""
    R = recv.shape[0]
    buf_seq: List[Any] = [("init", s) for s in range(n + 1)]
    buf_stg = list(buf_seq)
    for t in range(R):
        m = ("wire", t)
        rs = int(recv[t, r])
        if t + 1 < R:
            ss = int(send[t + 1, r])
            pre = buf_stg[ss]                      # packed pre-update
            buf_seq[rs] = m
            got_seq = buf_seq[ss]                  # packed post-update
            buf_stg[rs] = m
            got_stg = m if rs == ss else pre       # staged bypass
            if got_seq != got_stg:
                _find(out, "overlap-equivalence", loc,
                      f"rank {r} round {t}: pre-packed send slot {ss} is "
                      f"stale and not patched by the staged bypass "
                      f"(overlapped wire stream diverges)")
                return
        else:
            buf_seq[rs] = m
            buf_stg[rs] = m
    if buf_seq != buf_stg:
        _find(out, "overlap-equivalence", loc,
              f"rank {r}: overlapped final buffer diverges from the "
              f"sequential executor")


def _overlap_rev_replay(fwd: np.ndarray, acc: np.ndarray, n: int,
                        nslots: int, r: int, out: List[Finding],
                        loc: str) -> None:
    """One rank's reversed rounds, sequential vs staged (reduce /
    scatter layout; slot values are multisets of accumulated partials,
    drained slots hold the op identity = the empty multiset)."""
    R = fwd.shape[0]
    garbage = n
    # State after the initial capture+drain of round 0's forward, which
    # both executors run as the same plain acc_shuffle.
    buf_seq: List[Any] = [(("init", s),) for s in range(nslots)]
    if nslots > n + 1:
        buf_seq[n + 1] = _IDENT                    # identity slot
    buf_seq[int(fwd[0, r])] = _IDENT
    buf_stg = list(buf_seq)
    for t in range(R):
        m = ("wire", t)
        a_s = int(acc[t, r])
        f_s = int(fwd[t + 1, r]) if t + 1 < R else garbage
        # sequential: accumulate, then capture post-accumulate, drain
        buf_seq[a_s] = tuple(sorted(buf_seq[a_s] + (m,)))
        got_seq = buf_seq[f_s]
        buf_seq[f_s] = _IDENT
        # staged: capture pre-accumulate, bypass the coincident slot
        pre = buf_stg[f_s]
        combined = tuple(sorted(buf_stg[a_s] + (m,)))
        buf_stg[a_s] = combined
        got_stg = combined if a_s == f_s else pre
        buf_stg[f_s] = _IDENT
        if got_seq != got_stg:
            _find(out, "overlap-equivalence", loc,
                  f"rank {r} round {t}: pre-captured forward slot {f_s} "
                  f"misses a partial accumulated in round {t} (staged "
                  f"acc bypass missed; overlapped wire stream diverges)")
            return
    if buf_seq != buf_stg:
        _find(out, "overlap-equivalence", loc,
              f"rank {r}: overlapped final buffer diverges from the "
              f"sequential executor")


def _audit_overlap(ps: PhaseStatic, out: List[Finding], loc: str) -> None:
    """Replay every rank's rounds symbolically, staged vs sequential."""
    if ps.kind in ("broadcast", "allgather"):
        recv = ps.slots[0]
        if ps.kind == "broadcast":
            send = ps.slots[1]
        else:
            # The allgather executor derives root row j's send slot from
            # the recv table via Condition 2's base rotation; per virtual
            # rank that is exactly the rotated recv column.
            ranks = np.arange(ps.p)
            send = np.stack([recv[t][(ranks + ps.shifts[t]) % ps.p]
                             for t in range(recv.shape[0])])
        for r in range(ps.p):
            _overlap_fwd_replay(recv, send, ps.n, r, out, loc)
    else:
        fwd, acc = ps.slots
        nslots = ps.n + 2 if ps.kind == "reduce" else ps.n + 1
        for r in range(ps.p):
            _overlap_rev_replay(fwd, acc, ps.n, nslots, r, out, loc)


# ----------------------------------------------------------- phase audit


def audit_phase(ps: PhaseStatic, out: Optional[List[Finding]] = None,
                _verified: Optional[set] = None) -> List[Finding]:
    """Audit one phase's static tables; returns the findings list."""
    out = [] if out is None else out
    loc = (f"{ps.kind} p={ps.p} root={ps.root} n={ps.n}"
           + (f" axis={ps.axis}" if ps.axis else "")
           + (" overlap" if ps.overlap else ""))
    p, n, root = ps.p, ps.n, ps.root
    q = _q(p)
    R = _phase_rounds(p, n)
    garbage = n

    # -- structural sanity ------------------------------------------------
    if ps.direction not in ("fwd", "rev"):
        _find(out, "phase-direction", loc,
              f"unknown direction {ps.direction!r}")
        return out
    expect_nslots = n + 2 if ps.kind == "reduce" else n + 1
    if ps.nslots != expect_nslots:
        _find(out, "slot-layout", loc,
              f"nslots={ps.nslots}, expected {expect_nslots}")
    nslots = expect_nslots  # range-check against the true layout

    # -- round count vs the closed form ----------------------------------
    if len(ps.ks) != R or len(ps.shifts) != R:
        _find(out, "round-count", loc,
              f"{len(ps.ks)} rounds in tables, closed form "
              f"n-1+ceil(log2 p) gives {R}")
    for tab in ps.slots:
        if tab.shape != (len(ps.ks), p):
            _find(out, "table-shape", loc,
                  f"slot table shape {tab.shape} != ({len(ps.ks)}, {p})")
            return out  # nothing below is meaningful on malformed tables

    # -- immutability (the cache contract) -------------------------------
    for name, arr in list(zip(("slots[0]", "slots[1]"), ps.slots)) + [
            ("ks", np.asarray(ps.ks))]:
        if isinstance(arr, np.ndarray) and arr.flags.writeable:
            _find(out, "mutable-table", loc,
                  f"{name} is writeable; cached plan tables must be "
                  f"frozen (writeable=False)")

    # -- rotation consistency against the bundle -------------------------
    bundle = get_bundle(p, root)
    plan = bundle.round_plan(n)
    expected_ks = [k for k, _ in plan]
    if ps.direction == "rev":
        expected_ks = expected_ks[::-1]
    if list(int(k) for k in ps.ks) != expected_ks:
        _find(out, "ks-sequence", loc,
              f"skip-column sequence {list(map(int, ps.ks))} != "
              f"{ps.direction} round plan {expected_ks}")
    else:
        for t, k in enumerate(ps.ks):
            sk = int(bundle.skip[int(k)])
            want = sk if ps.direction == "fwd" else (p - sk) % p
            if ps.shifts[t] != want:
                _find(out, "rotation", loc,
                      f"round {t}: wire rotation {ps.shifts[t]} != "
                      f"{want} (skip[{int(k)}]={sk}, {ps.direction})")

    # -- clamped-slot consistency against the bundle ---------------------
    rebuilt = {
        "broadcast": broadcast_phase_static,
        "allgather": allgather_phase_static,
        "reduce": reduce_phase_static,
        "scatter": scatter_phase_static,
    }.get(ps.kind)
    if rebuilt is None:
        _find(out, "phase-kind", loc, f"unknown phase kind {ps.kind!r}")
        return out
    ref = rebuilt(bundle, n)
    if len(ref.slots) != len(ps.slots):
        _find(out, "table-arity", loc,
              f"{len(ps.slots)} slot tables, expected {len(ref.slots)}")
        return out
    for i, (got, want) in enumerate(zip(ps.slots, ref.slots)):
        if got.shape == want.shape and not np.array_equal(got, want):
            bad = int(np.argwhere(got != want)[0][0])
            _find(out, "bundle-consistency", loc,
                  f"slots[{i}] diverges from the bundle-derived clamp "
                  f"(first bad round {bad})")

    # -- slot range -------------------------------------------------------
    for i, tab in enumerate(ps.slots):
        if tab.size and (tab.min() < 0 or tab.max() >= nslots):
            _find(out, "slot-range", loc,
                  f"slots[{i}] addresses [{int(tab.min())}, "
                  f"{int(tab.max())}] outside the {nslots}-slot buffer")
            return out  # indexing below would be out of bounds

    ranks = np.arange(p)
    if ps.kind in ("broadcast", "allgather"):
        recv = ps.slots[0]
        # -- write-once: no two rounds write one rank's same data slot --
        for r in range(p):
            col = recv[:, r]
            real = col[col < n - 1]
            if len(real) != len(set(real.tolist())):
                vals, counts = np.unique(real, return_counts=True)
                dup = int(vals[counts > 1][0])
                _find(out, "write-once", loc,
                      f"rank {r} receives data slot {dup} in more than "
                      f"one round (write-write race)")
        if ps.kind == "broadcast":
            send = ps.slots[1]
            # -- exchange consistency (clamped Conditions 1-2) ----------
            for t in range(len(ps.ks)):
                sk = int(bundle.skip[int(ps.ks[t])])
                if not np.array_equal(send[t], recv[t][(ranks + sk) % p]):
                    _find(out, "exchange", loc,
                          f"round {t}: send slots are not the receivers' "
                          f"recv slots (Condition 2 violated)")
            # -- RAW order: only already-received slots are ever sent ---
            for r in range(p):
                if r == root:
                    continue
                seen: set = set()
                for t in range(len(ps.ks)):
                    s = int(send[t, r])
                    if s != garbage and s not in seen:
                        _find(out, "raw-send", loc,
                              f"rank {r} sends slot {s} in round {t} "
                              f"before ever receiving it")
                        break
                    seen.add(int(recv[t, r]))
    elif ps.kind in ("reduce", "scatter"):
        fwd, acc = ps.slots
        ident = n + 1
        if ps.kind == "reduce":
            # -- root pin: the root only ever ships the op identity -----
            if not np.all(fwd[:, root] == ident):
                _find(out, "root-pin", loc,
                      f"root fwd column not pinned to the identity slot "
                      f"{ident} (a live partial would leak the root)")
        # -- exchange consistency (reversed Conditions 1-2, clamped) ----
        for t in range(len(ps.ks)):
            sk = int(bundle.skip[int(ps.ks[t])])
            got = fwd[t]
            want = acc[t][(ranks - sk) % p]
            if ps.kind == "reduce":
                got = np.delete(got, root)
                want = np.delete(want, root)
            if not np.array_equal(got, want):
                _find(out, "exchange", loc,
                      f"round {t}: forwarded slots are not the receivers' "
                      f"acc slots (reversed Condition 2 violated)")
        if ps.kind == "reduce":
            # -- liveness: every accumulated real partial is forwarded --
            for r in range(p):
                if r == root:
                    continue
                future = [set() for _ in range(len(ps.ks) + 1)]
                for t in range(len(ps.ks) - 1, -1, -1):
                    future[t] = future[t + 1] | {int(fwd[t, r])}
                for t in range(len(ps.ks)):
                    s = int(acc[t, r])
                    if s < n and s not in future[t + 1]:
                        _find(out, "lost-partial", loc,
                              f"rank {r} accumulates slot {s} in round "
                              f"{t} but never forwards it (partial lost)")

    # -- overlap equivalence (double-buffered statics only) ---------------
    if ps.overlap:
        _audit_overlap(ps, out, loc)

    # -- schedule-level conditions (once per (p, root)) -------------------
    key = (p, root)
    if _verified is None or key not in _verified:
        try:
            verify_bundle(bundle)
        except AssertionError as e:
            _find(out, "schedule-conditions", loc, str(e))
        if _verified is not None:
            _verified.add(key)
    return out


def audit_statics(statics: Iterable[PhaseStatic],
                  _verified: Optional[set] = None) -> Report:
    """Audit a plan's ``statics`` tuple phase by phase."""
    findings: List[Finding] = []
    checked = 0
    verified = set() if _verified is None else _verified
    for ps in statics:
        audit_phase(ps, findings, verified)
        checked += 1
    return Report(findings=tuple(findings), checked=checked)


# ------------------------------------------------------------ plan audit


def _audit_phase_layout(statics, expect, loc, findings) -> None:
    """Check a plan's phase sequence matches (kind, p, root, n) tuples."""
    got = tuple((s.kind, s.p, s.root, s.n) for s in statics)
    if got != tuple(expect):
        _find(findings, "phase-layout", loc,
              f"phase sequence {got} != expected {tuple(expect)}")


def audit_plan(plan: Any) -> Report:
    """Audit any plan object exposing ``statics`` (device or host, flat
    or hierarchical -- dispatched by duck typing)."""
    statics = getattr(plan, "statics", None)
    if statics is None:
        return Report(findings=(Finding(
            "plan", "no-statics", repr(plan),
            "plan exposes no statics tuple to audit"),), checked=1)
    findings: List[Finding] = []
    verified: set = set()

    plan_overlap = getattr(plan, "overlap", None)
    if plan_overlap is not None:
        for s in statics:
            if s.overlap != plan_overlap:
                _find(findings, "overlap-flag", repr(plan),
                      f"plan overlap={plan_overlap} but a "
                      f"{s.kind} phase static carries "
                      f"overlap={s.overlap} (executor mode and audited "
                      f"tables disagree)")

    if hasattr(plan, "rounds_inter"):            # HierPlan
        loc = (f"hier-{plan.kind} mesh={plan.nodes}x{plan.cores} "
               f"root={plan.root} n=({plan.n_inter},{plan.n_intra})")
        scale = 2 if plan.kind == "allreduce" else 1
        rN = _phase_rounds(plan.nodes, plan.n_inter)
        rC = _phase_rounds(plan.cores, plan.n_intra)
        if plan.rounds_inter != scale * rN or plan.rounds_intra != scale * rC:
            _find(findings, "round-count", loc,
                  f"per-level rounds ({plan.rounds_inter}, "
                  f"{plan.rounds_intra}) != closed forms "
                  f"({scale * rN}, {scale * rC})")
        if plan.rounds != plan.rounds_inter + plan.rounds_intra:
            _find(findings, "round-count", loc,
                  f"total rounds {plan.rounds} != inter+intra "
                  f"{plan.rounds_inter + plan.rounds_intra}")
        if plan.nodes * plan.cores > 1:
            _audit_phase_layout(
                statics,
                _expected_hier_phases(plan.kind, plan.nodes, plan.cores,
                                      plan.n_inter, plan.n_intra, plan.root),
                loc, findings)
    elif hasattr(plan, "n_blocks"):              # CollectivePlan
        loc = (f"{plan.kind} p={plan.p} root={plan.root} "
               f"n={plan.n_blocks} backend={plan.backend}")
        scale = 2 if plan.kind in ("allreduce", "quantized_allreduce") else 1
        want = scale * _phase_rounds(plan.p, plan.n_blocks)
        if plan.rounds != want:
            _find(findings, "round-count", loc,
                  f"plan.rounds={plan.rounds} != closed form {want}")
        if plan.p > 1:
            root = plan.root
            _audit_phase_layout(
                statics,
                [(k, plan.p, root, plan.n_blocks)
                 for k in _expected_phases(plan.kind)],
                loc, findings)
    elif hasattr(plan, "ks"):                    # HostDataPlan
        loc = (f"host-{plan.kind} p={plan.p} root={plan.root} n={plan.n} "
               f"backend={plan.backend}")
        if getattr(plan.step, "backend", plan.backend) != plan.backend:
            _find(findings, "step-backend", loc,
                  f"round-step handle backend "
                  f"{getattr(plan.step, 'backend', None)!r} != plan "
                  f"backend {plan.backend!r}")
        if plan.p > 1:
            _audit_phase_layout(
                statics,
                [(k, plan.p, plan.root, plan.n)
                 for k in _expected_phases(plan.kind)],
                loc, findings)
            # identity: the audited arrays must BE the executed ones
            executed = {id(a) for a in plan.slots}
            for s in statics:
                for arr in s.slots:
                    if id(arr) not in executed:
                        _find(findings, "table-identity", loc,
                              "statics carry different array objects "
                              "than the plan executes (cache identity "
                              "broken)")
    elif hasattr(plan, "cores"):                 # HierHostPlan
        loc = (f"hier-host-{plan.kind} mesh={plan.nodes}x{plan.cores} "
               f"root={plan.root} n=({plan.n_inter},{plan.n_intra})")
        if plan.nodes * plan.cores > 1:
            _audit_phase_layout(
                statics,
                _expected_hier_phases(plan.kind, plan.nodes, plan.cores,
                                      plan.n_inter, plan.n_intra, plan.root),
                loc, findings)

    sub = audit_statics(statics, verified)
    return Report(findings=tuple(findings), checked=1) + sub


def _expected_hier_phases(kind, nodes, cores, nN, nC, root):
    """(kind, p, root, n) sequence a two-level plan must carry, derived
    independently of repro.core.hier."""
    rootN, rootC = divmod(int(root), int(cores))
    inter_b = [("broadcast", nodes, rootN, nN)] if nodes > 1 else []
    intra_b = [("broadcast", cores, rootC, nC)] if cores > 1 else []
    inter_r = [("reduce", nodes, rootN, nN)] if nodes > 1 else []
    intra_r = [("reduce", cores, rootC, nC)] if cores > 1 else []
    inter_g = [("allgather", nodes, rootN, nN)] if nodes > 1 else []
    intra_g = [("allgather", cores, rootC, nC)] if cores > 1 else []
    return {
        "broadcast": inter_b + intra_b,
        "reduce": intra_r + inter_r,
        "allreduce": intra_r + inter_r + inter_b + intra_b,
        "allgather": intra_g + inter_g,
        "allbroadcast": intra_g + inter_g,
    }[kind]


# ----------------------------------------------------- kind-level sweeps


def audit_kind(kind: str, p: int, n: int, root: int = 0,
               overlap: bool = False,
               _verified: Optional[set] = None) -> Report:
    """Audit the tables a flat plan of this spec would run (no mesh, no
    jax: works for any p, including sizes far beyond the local host).
    ``overlap=True`` audits the double-buffered executor's statics."""
    return audit_statics(statics_for_kind(kind, p, n, root, overlap=overlap),
                         _verified=_verified)


def audit_hier_kind(kind: str, nodes: int, cores: int, n_inter: int,
                    n_intra: int, root: int = 0,
                    _verified: Optional[set] = None) -> Report:
    """Audit the per-level tables of a two-level plan spec (the paper's
    36x32 topology audits in-process this way)."""
    if kind not in HIER_PLAN_KINDS:
        raise ValueError(f"unknown hier plan kind {kind!r} "
                         f"(use one of {HIER_PLAN_KINDS})")
    statics: List[PhaseStatic] = []
    for phase_kind, lp, lroot, ln in _expected_hier_phases(
            kind, int(nodes), int(cores), int(n_inter), int(n_intra), root):
        statics.extend(statics_for_kind(
            {"allgather": "allgather", "broadcast": "broadcast",
             "reduce": "reduce"}[phase_kind], lp, ln, lroot))
    return audit_statics(statics, _verified=_verified)


# --------------------------------------------------- immutability audits


def audit_bundle(bundle) -> Report:
    """``writeable=False`` audit of one cached schedule bundle."""
    findings: List[Finding] = []
    loc = f"bundle p={bundle.p} root={bundle.root}"
    for name in ("recv", "send"):
        arr = getattr(bundle, name)
        if isinstance(arr, np.ndarray) and arr.flags.writeable:
            _find(findings, "mutable-table", loc,
                  f"bundle.{name} is writeable", pass_name="cache")
    return Report(findings=tuple(findings), checked=1)


def _walk_arrays(value: Any, seen: set):
    """Yield every np.ndarray reachable from a plan-cache value through
    dataclasses, dicts, tuples and lists (jax arrays, callables, Mesh
    objects etc. are opaque leaves)."""
    if id(value) in seen:
        return
    seen.add(id(value))
    if isinstance(value, np.ndarray):
        yield value
    elif is_dataclass(value) and not isinstance(value, type):
        for f in fields(value):
            yield from _walk_arrays(getattr(value, f.name), seen)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _walk_arrays(v, seen)
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _walk_arrays(v, seen)


def audit_cache(cache: Optional[Dict[Any, Any]] = None) -> Report:
    """Immutability audit of every NumPy array reachable from the
    engine's process-wide plan cache (slot plans, host plans, device
    plans, quantized statics): all must carry ``writeable=False``."""
    cache = _engine._plan_cache if cache is None else cache
    findings: List[Finding] = []
    seen: set = set()
    checked = 0
    for key, value in list(cache.items()):
        checked += 1
        for arr in _walk_arrays(value, seen):
            if arr.flags.writeable:
                _find(findings, "mutable-cache-entry", f"key={key!r}",
                      f"cached array (shape {arr.shape}, dtype "
                      f"{arr.dtype}) is writeable; plan-cache entries "
                      f"must be frozen", pass_name="cache")
    return Report(findings=tuple(findings), checked=checked)
