"""CLI: ``python -m repro.analysis [--all|--plans|--kernels|--lint|--cache]``.

Runs the static passes over a representative grid -- every plan kind,
flat p across the interesting regimes (powers of two, primes, the
composite sizes the paper benchmarks) with non-trivial roots, the
two-level meshes up to the paper's 36x32 evaluation topology, host
plans on both round-step backends, every registered Pallas kernel --
and exits non-zero on any finding.  ``--bench PATH`` additionally
records per-pass wall time to a JSON file (the repo's
BENCH_analysis.json).

Nothing here executes a collective: plans are audited from their frozen
tables, kernels from traced jaxprs and index-map replay, sources from
their ASTs.  The flat/hier table sweeps run on the host plane for ANY p
-- no devices needed for 36x32.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from .lint import lint_repo
from .planaudit import (
    audit_bundle,
    audit_cache,
    audit_hier_kind,
    audit_kind,
    audit_plan,
    HIER_PLAN_KINDS,
    OVERLAP_KINDS,
    PLAN_KINDS,
)
from .report import Report

# Flat p-grid: powers of two, primes, +-1 neighbours, the paper's 36.
P_GRID = (2, 3, 4, 5, 7, 8, 11, 16, 17, 31, 32, 36, 63, 64)
N_GRID = (1, 4, 8)
#: Two-level meshes; (36, 32) is the paper's evaluation topology.
HIER_MESHES = ((2, 2), (2, 4), (6, 4), (36, 32))
#: Host-plan sweep (plan objects incl. executable round steps).
HOST_PS = (2, 3, 5, 8)
HOST_KINDS = ("broadcast", "allgather", "reduce", "quantized_allreduce")


def run_plans() -> Report:
    report = Report()
    verified: set = set()
    for kind in PLAN_KINDS:
        for p in P_GRID:
            for root in (0, p - 1):
                for n in N_GRID:
                    report = report + audit_kind(kind, p, n, root,
                                                 _verified=verified)
                    if kind in OVERLAP_KINDS:
                        # double-buffered statics: same tables, plus the
                        # overlap-equivalence replay
                        report = report + audit_kind(kind, p, n, root,
                                                     overlap=True,
                                                     _verified=verified)
    for kind in HIER_PLAN_KINDS:
        for nodes, cores in HIER_MESHES:
            report = report + audit_hier_kind(kind, nodes, cores,
                                              n_inter=4, n_intra=4,
                                              _verified=verified)
    # Host plans: real plan objects on both round-step backends (pallas
    # in interpret mode off-TPU), audited through their statics.
    from repro.core.comm import host_plan
    from repro.core.engine import get_bundle
    from repro.core.hier import hier_host_plan
    from repro.core.roundstep import BACKENDS

    for backend in BACKENDS:
        for kind in HOST_KINDS:
            for p in HOST_PS:
                plan = host_plan(kind, p, n=4, backend=backend)
                report = report + audit_plan(plan)
                if kind in OVERLAP_KINDS:
                    plan = host_plan(kind, p, n=4, backend=backend,
                                     overlap=True)
                    report = report + audit_plan(plan)
        for kind in HIER_PLAN_KINDS:
            plan = hier_host_plan(kind, 2, 4, 2, 4, backend=backend)
            report = report + audit_plan(plan)
    for p in P_GRID:
        report = report + audit_bundle(get_bundle(p, 0))
    return report


def run_kernels() -> Report:
    from .kernelaudit import audit_kernels

    return audit_kernels(ps=(2, 3, 5, 8), ns=(1, 4))


def run_lint() -> Report:
    return lint_repo()


def run_cache() -> Report:
    # After the other passes populated it, sweep the engine plan cache
    # for any thawed array (run last for maximal coverage).
    return audit_cache()


PASSES = (("plans", run_plans), ("kernels", run_kernels),
          ("lint", run_lint), ("cache", run_cache))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static plan auditor, Pallas race detector, repo lint.")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no pass is named)")
    for name, _fn in PASSES:
        ap.add_argument(f"--{name}", action="store_true",
                        help=f"run the {name} pass")
    ap.add_argument("--bench", metavar="PATH", default=None,
                    help="write per-pass wall-time JSON to PATH")
    args = ap.parse_args(argv)

    selected = [name for name, _fn in PASSES if getattr(args, name)]
    if args.all or not selected:
        selected = [name for name, _fn in PASSES]

    total = Report()
    bench = {}
    for name, fn in PASSES:
        if name not in selected:
            continue
        t0 = time.perf_counter()
        rep = fn()
        dt = time.perf_counter() - t0
        bench[name] = {"seconds": round(dt, 4), "checked": rep.checked,
                       "findings": len(rep.findings)}
        print(f"[{name}] {rep.summary()} in {dt:.2f}s")
        total = total + rep
    if args.bench:
        payload = {"passes": bench,
                   "total": {"checked": total.checked,
                             "findings": len(total.findings),
                             "seconds": round(sum(
                                 b["seconds"] for b in bench.values()), 4)}}
        Path(args.bench).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"bench written to {args.bench}")
    if not total.ok:
        print(f"FAILED: {len(total.findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"OK: {total.checked} item(s) audited, 0 findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
