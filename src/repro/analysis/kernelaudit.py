"""Pallas data-plane race detector for the schedule-driven kernels.

The kernels in :mod:`repro.kernels.block_pack` publish a machine-checkable
:class:`~repro.kernels.block_pack.KernelAudit` record: the grid, every
operand's BlockSpec index map (the *same* function objects the
``pallas_call`` was built with), which logical HBM storage each operand
addresses, the ``input_output_aliases`` dict, and a liveness predicate
saying at which grid points an input block's value is actually consumed.

Pallas executes the grid sequentially in lexicographic order but
*pipelines* the block DMAs: an input block may be fetched before a
logically earlier grid point's output write has landed.  Interpret mode
has no such pipeline, so any value that depends on reading back a block
a strictly earlier grid point wrote can differ between ``interpret=True``
CI and the compiled TPU run -- the exact hazard the fused kernels were
rewritten to avoid (read-only operand + staging scratch).  This pass
proves the absence of that hazard *statically*, by replaying the index
maps over the whole grid with the real schedule tables:

  * **write-write overlap**: two grid points writing the same block of
    one storage, outside the declared sequential drain dimension
    (``drain_dims`` -- the accumulate-then-drain sub-round rewriting one
    row's slot is by-design sequential);
  * **read-after-write alias**: a *live* input read of a block that a
    strictly earlier grid point wrote (dead fetches -- the alias
    operand's discarded block, the drain sub-round's staged-through
    reads -- cannot race);
  * **alias map consistency**: every ``input_output_aliases`` pair must
    address identical blocks at every grid point, else the alias
    rewrites a block the input never fetched;
  * **trace consistency**: the jaxpr actually traced from each kernel
    carries the registry's grid and alias pairs (the registry cannot
    silently drift from the shipped ``pallas_call``);
  * **dtype discipline**: traced output dtypes equal the declared
    ``out_dtypes`` contract -- accumulate in the buffer dtype, int8 wire
    + f32 scales in the quantized path, no silent widening/narrowing.

Schedule tables come from the same process-wide cached slot plans the
plans execute, so a clean audit speaks about the shipped data plane, not
a synthetic one.  This module imports jax (tracing only -- nothing is
executed); :mod:`repro.analysis` loads it lazily to keep the host-plane
entry points jax-free.
"""

from __future__ import annotations

import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .report import Finding, Report

__all__ = [
    "replay_kernel",
    "audit_kernel_trace",
    "audit_kernel",
    "audit_kernels",
    "schedule_scalars",
]

_DTYPES = ("float32", "bfloat16", "int32")  # acc paths under audit


def _find(out: List[Finding], check: str, location: str, message: str) -> None:
    out.append(Finding(pass_name="kernel", check=check, location=location,
                       message=message))


def _eval_map(index_map, g: Tuple[int, ...],
              scalars: Sequence[np.ndarray]) -> Tuple[int, ...]:
    """Evaluate a BlockSpec index map at concrete grid point g with the
    prefetched scalar tables (numpy stands in for the SMEM refs)."""
    return tuple(int(c) for c in index_map(*g, *scalars))


def replay_kernel(spec, scalars: Sequence[np.ndarray],
                  out: Optional[List[Finding]] = None,
                  location: str = "") -> List[Finding]:
    """Replay one kernel's index maps over its grid and prove the three
    structural properties (WW overlap, live RAW, alias-map agreement).

    ``spec`` is a :class:`~repro.kernels.block_pack.KernelAudit`;
    ``scalars`` the concrete int32 prefetch vectors (one per scalar
    name, typically a round row of the cached slot tables).
    """
    out = [] if out is None else out
    loc = location or spec.name
    if len(scalars) != len(spec.scalar_names):
        _find(out, "scalar-arity", loc,
              f"{len(scalars)} scalar vectors for prefetch names "
              f"{spec.scalar_names}")
        return out
    scalars = [np.asarray(s) for s in scalars]

    grid_points = list(itertools.product(*(range(d) for d in spec.grid)))
    order = {g: i for i, g in enumerate(grid_points)}

    # writes[(storage, block)] -> list of grid points that wrote it
    writes: Dict[Tuple[str, Tuple[int, ...]], List[Tuple[int, ...]]] = {}
    for op in spec.outputs:
        for g in grid_points:
            blk = _eval_map(op.index_map, g, scalars)
            key = (op.storage, blk)
            prev = writes.setdefault(key, [])
            for earlier in prev:
                diff = tuple(d for d in range(len(g)) if earlier[d] != g[d])
                if not all(d in spec.drain_dims for d in diff):
                    _find(out, "ww-overlap", f"{loc}@{g}",
                          f"output {op.name!r} rewrites {op.storage} block "
                          f"{blk} already written at grid point {earlier} "
                          f"(differing dims {diff} not all in drain_dims "
                          f"{spec.drain_dims})")
            prev.append(g)

    # live reads vs strictly-earlier writes (the pipeline hazard)
    for op in spec.inputs:
        for g in grid_points:
            if op.live is not None and not op.live(g):
                continue
            blk = _eval_map(op.index_map, g, scalars)
            for w in writes.get((op.storage, blk), ()):
                if order[w] < order[g]:
                    _find(out, "raw-alias", f"{loc}@{g}",
                          f"live input {op.name!r} reads {op.storage} block "
                          f"{blk} written at earlier grid point {w}; "
                          f"compiled prefetch may observe either value "
                          f"(interpret/compiled divergence)")

    # alias pairs must address the same block everywhere
    for in_idx, out_idx in spec.aliases:
        pos = in_idx - spec.num_scalar_prefetch
        if not (0 <= pos < len(spec.inputs)) or out_idx >= len(spec.outputs):
            _find(out, "alias-range", loc,
                  f"alias pair ({in_idx}, {out_idx}) outside the operand "
                  f"layout ({len(spec.inputs)} inputs + "
                  f"{spec.num_scalar_prefetch} prefetch, "
                  f"{len(spec.outputs)} outputs)")
            continue
        i_op, o_op = spec.inputs[pos], spec.outputs[out_idx]
        if i_op.storage != o_op.storage:
            _find(out, "alias-storage", loc,
                  f"aliased operands {i_op.name!r}/{o_op.name!r} declare "
                  f"different storages ({i_op.storage!r} vs "
                  f"{o_op.storage!r})")
        for g in grid_points:
            bi = _eval_map(i_op.index_map, g, scalars)
            bo = _eval_map(o_op.index_map, g, scalars)
            if bi != bo:
                _find(out, "alias-map", f"{loc}@{g}",
                      f"alias pair {i_op.name!r}->{o_op.name!r} fetches "
                      f"block {bi} but writes block {bo}; the in-place "
                      f"update would land in a block never fetched")
                break
    return out


# ----------------------------------------------------- trace consistency


def _traced_pallas_params(name: str, R: int, nslots: int, bs: int, nb: int,
                          dtype) -> Tuple[Optional[dict], Tuple]:
    """(pallas_call eqn params, traced out dtypes) for kernel ``name``.

    Tracing only -- jax.make_jaxpr never executes the kernel, so this is
    cheap and device-free.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import block_pack as bp

    buf = jnp.zeros((R, nslots, bs), dtype)
    msg = jnp.zeros((R, bs), dtype)
    idx = jnp.zeros((R,), jnp.int32)
    calls = {
        "block_pack": (lambda: bp.block_pack(buf, idx, interpret=True)),
        "block_unpack": (lambda: bp.block_unpack(buf, msg, idx,
                                                 interpret=True)),
        "block_shuffle": (lambda: bp.block_shuffle(buf, msg, idx, idx,
                                                   interpret=True)),
        "block_shuffle_staged": (lambda: bp.block_shuffle_staged(
            buf, msg, msg, idx, idx, interpret=True)),
        "block_acc_shuffle": (lambda: bp.block_acc_shuffle(
            buf, msg, idx, idx, op="sum", interpret=True)),
        "block_acc_shuffle_staged": (lambda: bp.block_acc_shuffle_staged(
            buf, msg, msg, idx, idx, op="sum", interpret=True)),
        "block_qacc_shuffle": (lambda: bp.block_qacc_shuffle(
            jnp.zeros((R, nslots, bs), jnp.float32),
            jnp.zeros((R, nslots, bs), jnp.float32),
            jnp.zeros((R, bs), jnp.int8),
            jnp.zeros((R, nb), jnp.float32),
            idx, idx, interpret=True)),
    }
    jaxpr = jax.make_jaxpr(calls[name])()
    outs = tuple(v.aval.dtype for v in jaxpr.jaxpr.outvars)
    for eqn in jaxpr.eqns:
        if "pallas" in eqn.primitive.name:
            return eqn.params, outs
    return None, outs


def audit_kernel_trace(name: str, *, R: int = 3, nslots: int = 4,
                       bs: int = 8, nb: int = 2,
                       out: Optional[List[Finding]] = None,
                       spec=None) -> List[Finding]:
    """Trace kernel ``name`` to a jaxpr and check the registry cannot
    have drifted from the shipped pallas_call: same grid, same alias
    pairs, declared output dtypes.  ``spec`` overrides the registry
    record (the negative tests inject corrupted ones)."""
    import numpy as _np

    from repro.kernels import block_pack as bp

    out = [] if out is None else out
    registry_spec = spec
    dtypes = ("float32",) if name == "block_qacc_shuffle" else _DTYPES
    for dt in dtypes:
        spec = registry_spec if registry_spec is not None else \
            bp.kernel_audit_spec(name, R=R, nslots=nslots, bs=bs, nb=nb)
        loc = f"{name}[{dt}]"
        params, traced_out = _traced_pallas_params(
            name, R, nslots, bs, nb, _np.dtype(dt))
        if params is None:
            _find(out, "trace-missing", loc,
                  "no pallas_call primitive in the traced jaxpr")
            continue
        gm = params.get("grid_mapping")
        grid = getattr(gm, "grid", None)
        if grid is not None and tuple(grid) != spec.grid:
            _find(out, "trace-grid", loc,
                  f"traced grid {tuple(grid)} != registry grid {spec.grid}")
        nsp = getattr(gm, "num_index_operands", None)
        if nsp is not None and nsp != spec.num_scalar_prefetch:
            _find(out, "trace-prefetch", loc,
                  f"traced num_index_operands {nsp} != registry "
                  f"{spec.num_scalar_prefetch}")
        ioa = params.get("input_output_aliases")
        if ioa is not None and tuple(sorted(tuple(map(int, p)) for p in ioa)) \
                != tuple(sorted(spec.aliases)):
            _find(out, "trace-alias", loc,
                  f"traced input_output_aliases {tuple(ioa)} != registry "
                  f"{spec.aliases}")
        want = tuple(_np.dtype(d) for d in spec.out_dtypes(_np.dtype(dt)))
        got = tuple(_np.dtype(d) for d in traced_out)
        if got != want:
            _find(out, "dtype-widening", loc,
                  f"traced output dtypes {tuple(str(d) for d in got)} != "
                  f"declared {tuple(str(d) for d in want)}")
    return out


# ------------------------------------------------------------ full sweep


def schedule_scalars(name: str, p: int, n: int,
                     root: int = 0) -> Tuple[int, List[Tuple[np.ndarray, ...]]]:
    """(nslots, per-round scalar vectors) for kernel ``name`` driven by
    the real cached slot plans of a p-rank n-block schedule.

    The replay then audits exactly the index-map/table combinations the
    round-step backends execute.
    """
    from repro.core.engine import get_bundle
    from repro.core.roundstep import broadcast_slot_plan, reduce_slot_plan

    bundle = get_bundle(p, root)
    if name in ("block_pack", "block_unpack", "block_shuffle",
                "block_shuffle_staged"):
        recv, send, _ks = broadcast_slot_plan(bundle, n)
        nslots = n + 1
        if name == "block_pack":
            rows = [(send[t],) for t in range(len(send))]
        elif name == "block_unpack":
            rows = [(recv[t],) for t in range(len(recv))]
        else:  # (staged) shuffle: unpack round t, pack round t+1
            rows = [(recv[t], send[t + 1]) for t in range(len(recv) - 1)]
        return nslots, rows
    fwd, acc, _ks = reduce_slot_plan(bundle, n)
    nslots = n + 2
    # accumulate round t, capture/drain round t+1
    return nslots, [(acc[t], fwd[t + 1]) for t in range(len(fwd) - 1)]


def audit_kernel(name: str, p: int, n: int, root: int = 0,
                 bs: int = 8) -> Report:
    """Structural replay of one kernel over every round of a real
    p-rank n-block schedule, plus the trace/dtype checks."""
    from repro.kernels import block_pack as bp

    findings: List[Finding] = []
    nslots, rows = schedule_scalars(name, p, n, root)
    nb = max(1, bs // 4)
    spec = bp.kernel_audit_spec(name, R=p, nslots=nslots, bs=bs, nb=nb)
    checked = 0
    for t, scalars in enumerate(rows):
        replay_kernel(spec, scalars, findings,
                      location=f"{name} p={p} n={n} round {t}")
        checked += 1
    audit_kernel_trace(name, R=p, nslots=nslots, bs=bs, nb=nb, out=findings)
    return Report(findings=tuple(findings), checked=checked + 1)


def audit_kernels(ps: Iterable[int] = (2, 3, 5, 8), ns: Iterable[int] = (1, 4),
                  names: Optional[Iterable[str]] = None) -> Report:
    """Audit every registered kernel against a grid of real schedules."""
    from repro.kernels import block_pack as bp

    report = Report()
    for name in (bp.KERNEL_NAMES if names is None else names):
        for p in ps:
            for n in ns:
                report = report + audit_kernel(name, int(p), int(n))
    return report
