"""Static analysis for the schedule stack: prove safety without running.

Three passes over the repo's *frozen artifacts* (cached plan tables,
kernel audit records, source text), one CLI (``python -m
repro.analysis``):

  * :mod:`repro.analysis.planaudit` -- per-round safety of any plan's
    static slot tables: write-once slots, RAW ordering, exchange
    consistency, closed-form round counts, bundle consistency, cache
    immutability;
  * :mod:`repro.analysis.kernelaudit` -- the Pallas data-plane race
    detector: replays every BlockSpec index map over the grid and flags
    write-write overlap, live read-back of earlier-written blocks (the
    interpret/compiled divergence hazard) and alias/dtype drift
    (imports jax for tracing; loaded lazily);
  * :mod:`repro.analysis.lint` -- AST conventions: frozen plan
    dataclasses, jax-free host-plane modules, no mutable defaults,
    api.md coverage.

Findings aggregate in :class:`repro.analysis.Report`;
``Report.raise_if_failed()`` turns any finding into an
:class:`AnalysisError`.  See docs/analysis.md.
"""

from .lint import lint_repo, lint_source
from .planaudit import (
    audit_bundle,
    audit_cache,
    audit_hier_kind,
    audit_kind,
    audit_phase,
    audit_plan,
    audit_statics,
    statics_for_kind,
)
from .report import AnalysisError, Finding, Report

__all__ = [
    "AnalysisError",
    "Finding",
    "Report",
    "audit_bundle",
    "audit_cache",
    "audit_hier_kind",
    "audit_kind",
    "audit_phase",
    "audit_plan",
    "audit_statics",
    "statics_for_kind",
    "lint_repo",
    "lint_source",
    "audit_kernel",
    "audit_kernels",
    "replay_kernel",
]

_KERNEL_EXPORTS = ("audit_kernel", "audit_kernels", "replay_kernel",
                   "audit_kernel_trace", "schedule_scalars")


def __getattr__(name):
    # kernelaudit needs jax; keep the package importable (and the plan /
    # lint passes runnable) on a NumPy-only host plane.
    if name in _KERNEL_EXPORTS:
        from . import kernelaudit

        return getattr(kernelaudit, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
