"""Gradient compression with error feedback (distributed-optimization trick).

Two int8-on-the-wire transports implement the lossy mean-allreduce:

  * ``transport="circulant"`` (default) -- the quantized circulant
    allreduce of :mod:`repro.core.comm` (``2(n-1)+2*ceil(log2 p)``
    rounds, the paper's round-optimal schedule with the wire carrying
    int8 blocks + per-block f32 scales and every requantization error
    captured in the fused round step);
  * ``transport="ring"`` -- the legacy ring reduce-scatter/all-gather
    (``2(p-1)`` hops), kept as the baseline.

Error-feedback convention (Karimireddy et al. 2019), used everywhere in
this module: **error leaves are f32 and live in SUM units** -- each rank
keeps exactly the quantization error *it generated* (per-hop
requantization + its share of the final quantize), so that

    exact_mean == returned_mean + psum(errors) / p        (completeness)

holds to f32 accumulation tolerance.  Feeding ``g + e`` into the next
mean-allreduce therefore restores the lost mass exactly.  Two historical
bugs made the old accounting first-order wrong:

  * per-hop requantization error was dropped with a comment calling it
    second order -- it is first order and compounds with p (each of the
    p-1 hops requantizes a running partial sum);
  * the final-quantize error was recorded in MEAN units (post ``/p``),
    undercounting the fed-back mass by a factor of p.

Non-finite gradients: quantization flags a block containing NaN/inf via
a NaN scale (see :mod:`repro.kernels.quant_ops`), so the block
dequantizes to all-NaN deterministically on every rank -- visible to
grad-norm guards -- while the error feedback for that block is exactly
zero (never poisoned).

Wire volume for m f32 elements: ~2m int8 bytes (+ scales) versus 8m f32
bytes for an uncompressed allreduce -- a 4x reduction the roofline's
collective term sees directly; the circulant transport additionally
replaces the ring's 2(p-1) latency terms with 2(n-1)+2*ceil(log2 p)
(see docs/gradsync.md for the full table).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant_ops import (
    QBLOCK,
    block_nonfinite,
    dequant_blocks,
    quant_blocks,
    quant_error,
)

#: Quantization block length (elements sharing one f32 scale).
BLOCK = QBLOCK

__all__ = [
    "BLOCK",
    "quantize_int8",
    "dequantize_int8",
    "block_nonfinite",
    "init_error_state",
    "compressed_psum_ring",
    "compressed_allreduce_tree",
    "BucketSpec",
    "make_bucket_spec",
    "bucketize",
    "unbucketize",
    "init_grad_sync_state",
    "compressed_grad_sync",
    "streamed_sync_params",
]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization of a [N] f32 vector
    (N % BLOCK == 0) -> (q [nb, BLOCK] int8, scale [nb, 1] f32).

    A block containing any NaN/inf gets a NaN scale (the per-block
    nonfinite flag, see :func:`block_nonfinite`); its finite lanes are
    still quantized against the finite amax, so a single bad lane no
    longer silently poisons the other 255.
    """
    return quant_blocks(x.reshape(-1, BLOCK))


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8` -> flat [N] f32 (flagged blocks
    dequantize to all-NaN deterministically)."""
    return dequant_blocks(q, scale).reshape(-1)


def init_error_state(params):
    """Zero-initialized error-feedback state: f32 leaves regardless of
    the gradient dtype (bf16/f16 error state would quantize the
    feedback itself away)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _rot(p: int, s: int):
    return [(r, (r + s) % p) for r in range(p)]


def compressed_psum_ring(flat: jnp.ndarray, axis_name: str, p: int):
    """int8 ring all-reduce (mean) of a flat f32 vector inside shard_map.

    flat length must be divisible by p * BLOCK (caller pads).  Returns
    ``(mean, err)``: the mean-reduced vector and this rank's locally
    generated quantization error in SUM units (every per-hop
    requantization error plus the final quantize of the segment this
    rank owns), satisfying the completeness invariant of the module
    docstring.
    """
    if p == 1:
        return flat, jnp.zeros_like(flat)
    segs = flat.reshape(p, -1)            # [p, m/p]
    r = jax.lax.axis_index(axis_name)
    err = jnp.zeros_like(segs)

    # ---- reduce-scatter: after p-1 hops rank r holds the full sum of
    # segment r.  Each hop ships the partially-reduced segment as int8
    # (+ f32 block scales); partials accumulate locally in f32.  The
    # requantization error of every hop is captured into the row of the
    # segment being shipped (hop h ships segment (r+1+h) % p, so each
    # row is written exactly once).
    send_seg = jnp.take(segs, (r + 1) % p, axis=0)
    for h in range(p - 1):
        q, s = quantize_int8(send_seg)
        eh = quant_error(send_seg.reshape(-1, BLOCK), q, s).reshape(-1)
        err = jax.lax.dynamic_update_slice(
            err, eh[None], ((r + 1 + h) % p, 0))
        q = jax.lax.ppermute(q, axis_name, _rot(p, p - 1))  # r -> r-1
        s = jax.lax.ppermute(s, axis_name, _rot(p, p - 1))
        got = dequantize_int8(q, s)
        nxt = (r + 2 + h) % p
        send_seg = jnp.take(segs, nxt, axis=0) + got

    # ---- all-gather the reduced segment SUMS (int8 on the wire); the
    # final-quantize error stays in sum units in this rank's own row.
    q, s = quantize_int8(send_seg)
    err = jax.lax.dynamic_update_slice(
        err, quant_error(send_seg.reshape(-1, BLOCK), q, s).reshape(-1)[None],
        (r, 0))
    out = jnp.zeros_like(segs)
    out = jax.lax.dynamic_update_slice(out, dequantize_int8(q, s)[None],
                                       (r, 0))
    cur_q, cur_s = q, s
    for h in range(1, p):
        cur_q = jax.lax.ppermute(cur_q, axis_name, _rot(p, 1))
        cur_s = jax.lax.ppermute(cur_s, axis_name, _rot(p, 1))
        src = (r - h) % p
        out = jax.lax.dynamic_update_slice(
            out, dequantize_int8(cur_q, cur_s)[None], (src, 0)
        )
    return out.reshape(-1) / p, err.reshape(-1)


def _cast_with_delta(red: jnp.ndarray, dtype) -> Tuple[jnp.ndarray,
                                                       jnp.ndarray]:
    """Downcast the f32 mean to the gradient dtype, returning the cast
    value and the per-element loss.  Every rank sees the same loss, so
    adding it to each rank's error leaf injects p * delta into the next
    sum -- exactly the delta the next mean needs (sum-unit convention).
    Non-finite deltas (NaN gradients) contribute zero, like
    quant_error."""
    cast = red.astype(dtype)
    if np.dtype(dtype) == np.float32:
        return cast, jnp.zeros_like(red)
    delta = red - cast.astype(jnp.float32)
    return cast, jnp.where(jnp.isfinite(delta), delta, 0.0)


def compressed_allreduce_tree(grads, errors, axis_name: str, p: int, *,
                              transport: str = "circulant",
                              backend: str = "jnp",
                              n_blocks: Optional[int] = None,
                              qblock: Optional[int] = None):
    """Lossy mean-allreduce of a gradient pytree with error feedback.

    Must be called inside shard_map over ``axis_name``.  ``errors`` is
    the previous step's error state (f32 leaves, SUM units; start from
    :func:`init_error_state`).  Gradient leaves may be bf16/f16/f32:
    sub-f32 leaves are widened to f32 for the transport and the mean is
    cast back, with the downcast loss folded into the returned error
    state (the error state itself always stays f32).  Ragged leaf sizes
    are padded internally; the padded tail's error is folded back into
    the last real element, so truncation never drops error mass.
    Returns ``(mean_grads, new_errors)``.
    """
    if transport not in ("circulant", "ring"):
        raise ValueError(f"unknown transport {transport!r} "
                         "(use 'circulant' or 'ring')")
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    targets = [g.astype(jnp.float32).reshape(-1) + e.reshape(-1)
               for g, e in zip(flat_g, flat_e)]

    if transport == "circulant":
        from repro.core.comm import circulant_qallreduce_body

        sums, errs = circulant_qallreduce_body(
            targets, axis_name, p, n_blocks=n_blocks, backend=backend,
            qblock=qblock)
        means = [s / p for s in sums]
    else:
        qb = BLOCK if qblock is None else int(qblock)
        means, errs = [], []
        for tgt in targets:
            size = tgt.shape[0]
            pad = (-size) % (p * qb)
            red, e = compressed_psum_ring(jnp.pad(tgt, (0, pad)),
                                          axis_name, p)
            # fold the padded tail's error back into the last real
            # element (provably zero for exact-zero padding, but the
            # truncation must never be able to drop error mass).
            e = e[:size].at[size - 1].add(jnp.sum(e[size:]))
            means.append(red[:size])
            errs.append(e)

    outs, new_errs = [], []
    for g, m, e in zip(flat_g, means, errs):
        cast, delta = _cast_with_delta(m, g.dtype)
        outs.append(cast.reshape(g.shape))
        new_errs.append((e + delta).reshape(g.shape))
    return treedef.unflatten(outs), treedef.unflatten(new_errs)


# ----------------------------------------------------- gradient buckets
#
# The trainer syncs gradients per *bucket*, not per leaf: a frozen
# BucketSpec groups leaves greedily (flatten order) into ~bucket_bytes
# f32 buckets, so one quantized-allreduce plan per bucket spec is frozen
# once and reused every step via the process-wide plan cache, and small
# leaves amortize round latency instead of each paying it.


@dataclass(frozen=True)
class BucketSpec:
    """Frozen leaf->bucket assignment for a parameter tree (hashable, so
    it can key plan caches).  ``assignment[i]`` is the bucket of leaf i
    (flatten order), ``offsets[i]`` its element offset inside that
    bucket, ``bucket_sizes[b]`` the total f32 elements of bucket b."""

    leaf_sizes: Tuple[int, ...]
    assignment: Tuple[int, ...]
    offsets: Tuple[int, ...]
    bucket_sizes: Tuple[int, ...]

    @property
    def num_buckets(self) -> int:
        return len(self.bucket_sizes)


def make_bucket_spec(params, bucket_bytes: int = 4 << 20) -> BucketSpec:
    """Greedy bucketization of a pytree's leaves in flatten order.

    ``params`` may hold arrays or ``ShapeDtypeStruct``s.  Buckets are
    filled to ~``bucket_bytes`` of f32 payload (4 bytes/element); a
    leaf larger than the budget gets its own bucket.
    """
    leaves = jax.tree.leaves(params)
    if not leaves:
        raise ValueError("params tree has no array leaves")
    budget = max(1, int(bucket_bytes) // 4)
    sizes, assignment, offsets, bucket_sizes = [], [], [], []
    cur = 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        if bucket_sizes and cur + n > budget and cur > 0:
            bucket_sizes[-1] = cur
            bucket_sizes.append(0)
            cur = 0
        if not bucket_sizes:
            bucket_sizes.append(0)
        assignment.append(len(bucket_sizes) - 1)
        offsets.append(cur)
        sizes.append(n)
        cur += n
    bucket_sizes[-1] = cur
    return BucketSpec(leaf_sizes=tuple(sizes), assignment=tuple(assignment),
                      offsets=tuple(offsets),
                      bucket_sizes=tuple(bucket_sizes))


def bucketize(tree, spec: BucketSpec) -> List[jnp.ndarray]:
    """Flatten a pytree into ``spec``'s f32 bucket vectors."""
    leaves = jax.tree.leaves(tree)
    if len(leaves) != len(spec.leaf_sizes):
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{len(spec.leaf_sizes)}")
    parts: List[List[jnp.ndarray]] = [[] for _ in spec.bucket_sizes]
    for leaf, b in zip(leaves, spec.assignment):
        parts[b].append(leaf.astype(jnp.float32).reshape(-1))
    out = []
    for b, chunk in enumerate(parts):
        v = jnp.concatenate(chunk) if len(chunk) > 1 else chunk[0]
        if v.shape[0] != spec.bucket_sizes[b]:
            raise ValueError(f"bucket {b} has {v.shape[0]} elements, "
                             f"spec expects {spec.bucket_sizes[b]}")
        out.append(v)
    return out


def unbucketize(flats: Sequence[jnp.ndarray], spec: BucketSpec, like):
    """Inverse of :func:`bucketize`: slice bucket vectors back into a
    tree shaped (and dtyped) like ``like``.  Returns ``(tree, deltas)``
    where ``deltas`` are per-bucket f32 downcast-loss vectors (zero for
    f32 leaves) for the error-feedback accounting."""
    leaves, treedef = jax.tree.flatten(like)
    outs = []
    deltas = [jnp.zeros((s,), jnp.float32) for s in spec.bucket_sizes]
    for leaf, b, off, n in zip(leaves, spec.assignment, spec.offsets,
                               spec.leaf_sizes):
        sl = jax.lax.dynamic_slice(flats[b], (off,), (n,))
        cast, delta = _cast_with_delta(sl, leaf.dtype)
        outs.append(cast.reshape(leaf.shape))
        deltas[b] = jax.lax.dynamic_update_slice(deltas[b], delta, (off,))
    return treedef.unflatten(outs), deltas


def init_grad_sync_state(spec: BucketSpec, dp: int = 1):
    """Zero error-feedback buckets for :func:`compressed_grad_sync`:
    a tuple of [dp, bucket_size] f32 arrays (leading axis sharded over
    the dp axis by the trainer; ``dp=1`` for unsharded use)."""
    return tuple(jnp.zeros((dp, s), jnp.float32) for s in spec.bucket_sizes)


def compressed_grad_sync(grads, err_buckets, axis_name: str, p: int,
                         spec: BucketSpec, *, backend: str = "jnp",
                         n_blocks: Optional[int] = None,
                         qblock: Optional[int] = None):
    """Bucketized quantized-circulant gradient sync (inside shard_map).

    ``grads``: the local (unreduced) gradient pytree; ``err_buckets``: a
    sequence of flat [bucket_size] f32 error vectors (this rank's rows
    of :func:`init_grad_sync_state`).  All buckets ride ONE quantized
    circulant allreduce call -- one shared schedule, one plan.  Returns
    ``(mean_grads, new_err_buckets)`` with mean_grads in the gradient
    dtypes and errors satisfying the completeness invariant.
    """
    from repro.core.comm import circulant_qallreduce_body

    flats = bucketize(grads, spec)
    targets = [f + e.reshape(-1) for f, e in zip(flats, err_buckets)]
    sums, errs = circulant_qallreduce_body(
        targets, axis_name, p, n_blocks=n_blocks, backend=backend,
        qblock=qblock)
    means = [s / p for s in sums]
    mean_tree, deltas = unbucketize(means, spec, grads)
    new_errs = tuple(e + d for e, d in zip(errs, deltas))
    return mean_tree, new_errs


# ------------------------------------------------- streamed bucket sync
#
# The bucket-at-a-time alternative to compressed_grad_sync: instead of
# syncing the fully materialized gradient after the backward completes,
# each parameter bucket is wrapped in a custom_vjp identity whose
# BACKWARD rule runs that bucket's quantized circulant allreduce on the
# incoming cotangent.  Reverse-mode AD reaches a bucket's marker as soon
# as the last layer touching it has been differentiated, so bucket k's
# allreduce enters the graph with no data dependence on the still-
# pending backward of earlier layers -- XLA's scheduler can run the
# collective while that compute proceeds (bucket streaming).  The new
# error-feedback state leaves the backward as the cotangent of the
# error input; gradient accumulation rides in as an explicit ``acc``
# operand (custom_vjp rules must not close over tracers).


def _leaf_meta(leaves) -> Tuple[Tuple[Tuple[int, ...], Any, int], ...]:
    return tuple((tuple(leaf.shape), leaf.dtype,
                  int(np.prod(leaf.shape)) if leaf.shape else 1)
                 for leaf in leaves)


def _make_bucket_sync(meta, axis_name: str, p: int, backend: str,
                      accum_scale: float, n_blocks: Optional[int],
                      qblock: Optional[int]):
    """Build the per-bucket custom_vjp sync marker.

    ``sync(err, acc, *leaves)`` is the identity on ``leaves``; its VJP
    returns ``(new_err, 0, *synced_cts)`` where ``synced_cts`` is the
    lossy mean of ``(acc + cotangents) * accum_scale + err`` across the
    ``axis_name`` ranks and ``new_err`` the updated error-feedback
    bucket (SUM units, downcast deltas folded in)."""

    @jax.custom_vjp
    def sync(err, acc, *leaves):
        return leaves

    def fwd(err, acc, *leaves):
        return leaves, (err, acc)

    def bwd(res, cts):
        err, acc = res
        from repro.core.comm import circulant_qallreduce_body

        parts = [ct.astype(jnp.float32).reshape(-1) for ct in cts]
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        target = (acc + flat) * accum_scale + err
        sums, errs = circulant_qallreduce_body(
            [target], axis_name, p, n_blocks=n_blocks, backend=backend,
            qblock=qblock)
        mean = sums[0] / p
        new_err = errs[0].reshape(-1)
        out_cts, off = [], 0
        for shape, dtype, size in meta:
            sl = jax.lax.dynamic_slice(mean, (off,), (size,))
            cast, delta = _cast_with_delta(sl, dtype)
            out_cts.append(cast.reshape(shape))
            new_err = jax.lax.dynamic_update_slice(
                new_err, jax.lax.dynamic_slice(new_err, (off,), (size,))
                + delta, (off,))
            off += size
        return (new_err, jnp.zeros_like(acc)) + tuple(out_cts)

    sync.defvjp(fwd, bwd)
    return sync


def streamed_sync_params(params, err_buckets, acc_buckets,
                         spec: BucketSpec, axis_name: str, p: int, *,
                         backend: str = "jnp", accum_scale: float = 1.0,
                         n_blocks: Optional[int] = None,
                         qblock: Optional[int] = None):
    """Wrap each parameter bucket in a streamed sync marker (inside
    shard_map over ``axis_name``).

    Returns a tree identical to ``params`` in the forward.  Under
    ``jax.value_and_grad(loss, argnums=(params, err_buckets))`` of a
    loss computed THROUGH the returned tree, the params gradient is the
    error-fed lossy mean of ``(acc_buckets + local_grads) * accum_scale``
    -- synced bucket by bucket as the backward produces each bucket's
    cotangent, so bucket k's allreduce overlaps the backward of the
    layers feeding buckets k+1.. -- and the err_buckets gradient is the
    new error-feedback state (the same SUM-unit convention as
    :func:`compressed_grad_sync`).

    ``acc_buckets`` carries previously accumulated raw gradient buckets
    (zeros when there is no accumulation); ``accum_scale`` is the
    microbatch-mean factor applied to ``acc + grad`` before the sync.
    """
    leaves, treedef = jax.tree.flatten(params)
    if len(leaves) != len(spec.leaf_sizes):
        raise ValueError(f"params tree has {len(leaves)} leaves, spec "
                         f"expects {len(spec.leaf_sizes)}")
    if len(err_buckets) != spec.num_buckets:
        raise ValueError(f"{len(err_buckets)} error buckets, spec expects "
                         f"{spec.num_buckets}")
    groups: List[List[Any]] = [[] for _ in spec.bucket_sizes]
    for leaf, b in zip(leaves, spec.assignment):
        groups[b].append(leaf)
    synced: List[List[Any]] = []
    for b, group in enumerate(groups):
        sync = _make_bucket_sync(_leaf_meta(group), axis_name, p, backend,
                                 float(accum_scale), n_blocks, qblock)
        synced.append(list(sync(err_buckets[b].reshape(-1),
                                acc_buckets[b].reshape(-1), *group)))
    # stitch the bucket groups back into flatten order
    out, taken = [], [0] * spec.num_buckets
    for b in spec.assignment:
        out.append(synced[b][taken[b]])
        taken[b] += 1
    return treedef.unflatten(out)
