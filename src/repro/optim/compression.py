"""Gradient compression with error feedback (distributed-optimization trick).

``compressed_psum_ring`` is an int8-on-the-wire all-reduce implemented as
a ring reduce-scatter followed by a ring all-gather, both transporting
int8 payloads (plus tiny per-block f32 scales) via ``lax.ppermute``.
Partial sums are kept in int32/float32 locally and re-quantized before
each hop; the re-quantization error is returned to the caller and folded
into the next step's gradient ("error feedback", Karimireddy et al.
2019), keeping the optimizer unbiased to first order.

Wire volume: 2*(p-1)/p * m bytes of int8 (+ scales) versus
2*(p-1)/p * 4m bytes for an f32 ring all-reduce -- a 4x reduction, which
the roofline's collective term sees directly.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization of a [N] f32 vector (N % BLOCK == 0)."""
    blocks = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _rot(p: int, s: int):
    return [(r, (r + s) % p) for r in range(p)]


def compressed_psum_ring(flat: jnp.ndarray, axis_name: str, p: int):
    """int8 ring all-reduce (mean) of a flat f32 vector inside shard_map.

    flat length must be divisible by p * BLOCK (caller pads).  Returns the
    mean-reduced vector and the local quantization error (for feedback).
    """
    if p == 1:
        return flat, jnp.zeros_like(flat)
    segs = flat.reshape(p, -1)            # [p, m/p]
    r = jax.lax.axis_index(axis_name)

    # ---- reduce-scatter: after p-1 hops rank r holds the full sum of
    # segment r.  Each hop ships the partially-reduced segment as int8
    # (+ f32 block scales); partials accumulate locally in f32.
    send_seg = jnp.take(segs, (r + 1) % p, axis=0)
    for h in range(p - 1):
        q, s = quantize_int8(send_seg)
        q = jax.lax.ppermute(q, axis_name, _rot(p, p - 1))  # r -> r-1
        s = jax.lax.ppermute(s, axis_name, _rot(p, p - 1))
        got = dequantize_int8(q, s)
        nxt = (r + 2 + h) % p
        send_seg = jnp.take(segs, nxt, axis=0) + got
    my_sum = send_seg / p                 # mean of segment r
    # (per-hop requantization errors are second order and not fed back;
    # the final quantization below is covered by error feedback.)

    # ---- all-gather the reduced segments (int8 on the wire)
    q, s = quantize_int8(my_sum)
    e_local = my_sum - dequantize_int8(q, s)
    out = jnp.zeros_like(segs)
    out = jax.lax.dynamic_update_slice(out, dequantize_int8(q, s)[None], (r, 0))
    cur_q, cur_s = q, s
    for h in range(1, p):
        cur_q = jax.lax.ppermute(cur_q, axis_name, _rot(p, 1))
        cur_s = jax.lax.ppermute(cur_s, axis_name, _rot(p, 1))
        src = (r - h) % p
        out = jax.lax.dynamic_update_slice(
            out, dequantize_int8(cur_q, cur_s)[None], (src, 0)
        )
    err_total = jnp.zeros_like(segs).at[r].set(e_local).reshape(-1)
    return out.reshape(-1), err_total


def compressed_allreduce_tree(grads, errors, axis_name: str, p: int):
    """Apply compressed_psum_ring leaf-wise with error feedback.

    grads/errors: pytrees of f32 leaves (must be called inside shard_map
    over ``axis_name`` with every leaf replicated across that axis aside
    from the gradient values themselves).
    Returns (mean_grads, new_errors).
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        n = target.size
        pad = (-n) % (p * BLOCK)
        flat = jnp.pad(target.reshape(-1), (0, pad))
        red, err = compressed_psum_ring(flat, axis_name, p)
        red = red[:n].reshape(g.shape)
        err = err[:n].reshape(g.shape)
        return red.astype(g.dtype), err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
