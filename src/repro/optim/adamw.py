"""AdamW optimizer (pure functions, pytree state) with:

  * configurable moment dtype (bf16 moments shave 8 bytes/param off the
    optimizer footprint -- required to fit deepseek-v3-671b on 512 chips),
  * global-norm gradient clipping,
  * decoupled weight decay,
  * linear-warmup cosine schedule helper.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"      # "float32" | "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10000


def _mdtype(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def init_opt_state(cfg: AdamWConfig, params):
    dt = _mdtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    dt = _mdtype(cfg)
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu32.astype(dt), nu32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "grad_norm": gnorm,
        "lr": lr,
    }
