"""Serving engine: prefill + batched decode with KV caches.

``make_prefill_step`` / ``make_decode_step`` build the two jit-able
step functions the dry-run lowers (decode_32k / long_500k lower
``serve_step`` = one decode step against a full-length cache, per the
assignment).  ``ServeLoop`` is a small continuous-batching driver for
the runnable example: requests join a fixed-slot batch, finished slots
are refilled, greedy sampling."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache, prefill


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, memory_embeds=None):
        return prefill(params, cfg, tokens, memory_embeds=memory_embeds)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Minimal continuous-batching loop over fixed batch slots (CPU demo)."""

    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_seq: int = 128):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.cache = init_cache(cfg, batch_slots, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t)
        )
        self.queue: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _reset_slot(self, i: int):
        """Zero slot i's recurrent state and position (new request)."""
        def zero_slot(key, arr):
            if key == "pos_idx":
                return arr.at[i].set(0)
            if key == "memory":
                return arr
            # stacked caches are [R, B, ...]; zero batch index i
            if arr.ndim >= 2 and arr.shape[1] == self.B:
                return arr.at[:, i].set(0)
            return arr
        self.cache = {k: zero_slot(k, v) for k, v in self.cache.items()}

    def _admit(self):
        for i in range(self.B):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self._reset_slot(i)
                # feed the prompt token-by-token (prefill-as-decode keeps
                # the demo simple; production uses the prefill step)
                req._pending = list(req.prompt)

    def step(self) -> bool:
        """One decode step over the batch.  Returns True if any slot active."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        tokens = np.zeros((self.B, 1), np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req._pending:
                tokens[i, 0] = req._pending.pop(0)
            elif req.out:
                tokens[i, 0] = req.out[-1]
            else:
                tokens[i, 0] = req.prompt[-1]
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tokens))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if not req._pending:  # prompt fully fed -> collecting output
                req.out.append(int(nxt[i]))
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.slot_req[i] = None
        return True

    def run(self, max_steps: int = 1000) -> List[Request]:
        finished = []
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return finished
