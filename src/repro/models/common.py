"""Model configuration dataclasses shared by every architecture family."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 6
    n_shared: int = 2          # shared (always-on) experts
    d_expert: int = 1408       # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 1024
    head_dim: Optional[int] = None   # default d_model // n_heads
    rope_theta: float = 1e4
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA width (h2o-danube)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (zamba2): one shared attention block applied every N layers
    shared_attn_every: int = 0
    # vlm (llama-3.2-vision): cross-attention layer every N layers
    cross_attn_every: int = 0
    n_image_tokens: int = 1601       # stub frontend output length
    # encdec (whisper)
    encoder_layers: int = 0
    n_audio_frames: int = 1500       # stub conv frontend output length
    # deepseek-v3 multi-token prediction: extra MTP block predicting t+2
    mtp: bool = False

    max_seq: int = 8192

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (for 6*N*D model-FLOPs accounting)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        per_layer = 0
        if self.family == "ssm" or (self.family == "hybrid" and self.ssm):
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            per_layer = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj
                + conv_ch * s.d_conv
                + nh  # A_log
                + nh  # D
                + d_in * d  # out_proj
                + d  # norm
            )
        if self.family in ("dense", "vlm", "encdec") or (
            self.family == "moe" and self.mla is None
        ):
            hd = self.hd
            attn = d * (self.n_heads * hd + 2 * self.n_kv_heads * hd) + self.n_heads * hd * d
            per_layer = attn + 2 * d
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
                + m.q_lora_rank + m.kv_lora_rank
            )
            per_layer = attn + 2 * d
        if self.family in ("dense", "vlm", "encdec"):
            per_layer += 3 * d * self.d_ff  # SwiGLU
        if self.family == "moe":
            mo = self.moe
            per_layer += d * mo.n_experts  # router
            per_layer += (mo.n_experts + mo.n_shared) * 3 * d * mo.d_expert
        total += L * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            hd = self.hd
            shared = (
                d * (self.n_heads * hd + 2 * self.n_kv_heads * hd)
                + self.n_heads * hd * d
                + 3 * d * self.d_ff
                + 2 * d
            )
            total += shared  # one shared block
        if self.family == "vlm" and self.cross_attn_every:
            pass  # cross-attn layers replace self-attn layers; same count
        if self.family == "encdec":
            total += self.encoder_layers * per_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        all_experts = self.n_layers * (mo.n_experts + mo.n_shared) * 3 * self.d_model * mo.d_expert
        active_experts = self.n_layers * (mo.top_k + mo.n_shared) * 3 * self.d_model * mo.d_expert
        return int(full - all_experts + active_experts)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
