"""Mamba2 / SSD (state-space duality) block, chunked scan formulation.

Follows Dao & Gu (arXiv:2405.21060): per head h with scalar decay
a_t = exp(dt_t * A_h), state S in R^{N x P}:

    S_t = a_t S_{t-1} + dt_t B_t x_t^T ,   y_t = C_t^T S_t + D_h x_t

The chunked algorithm splits the sequence into chunks of length Q,
computes the intra-chunk quadratic (dual) form, carries inter-chunk
states with a `lax.scan`, and adds the inter-chunk contribution.  The
single-token recurrence (`ssd_decode_step`) is the O(1)-per-token decode
path; the depthwise conv frontend keeps a (d_conv-1)-deep state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig
from .layers import dense_init, init_rms_norm, rms_norm


def ssm_init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 4)
    return {
        # projects to [z (gate), x, B, C, dt]
        "in_proj": dense_init(
            ks[0], d, 2 * d_in + 2 * s.n_groups * s.d_state + nh, dtype
        ),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": init_rms_norm(d_in),
        "out_proj": dense_init(ks[3], d_in, d, dtype),
    }


def _split_proj(proj, cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    gs = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * gs], axis=-1)
    return z, xbc, dt, d_in, nh, gs


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv along seq.  xbc: [B, S, Cch]; w: [K, Cch]."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state  # [B, K-1, Cch]
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1) :] if K > 1 else pad
    return jax.nn.silu(out), new_state


def ssd_chunked(x, B_, C_, dt, A_log, D, chunk: int):
    """Chunked SSD scan.

    x:  [B, S, H, P]   (values)
    B_: [B, S, G, N]   (input projections; broadcast over H//G heads)
    C_: [B, S, G, N]
    dt: [B, S, H]      (positive step sizes)
    Returns y: [B, S, H, P].
    """
    Bsz, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    A = -jnp.exp(A_log)                                   # [H] negative
    xc = x.reshape(Bsz, nc, Q, H, Pd)
    Bc = B_.reshape(Bsz, nc, Q, G, N)
    Cc = C_.reshape(Bsz, nc, Q, G, N)
    dtc = dt.reshape(Bsz, nc, Q, H)
    da = dtc * A                                          # [B,nc,Q,H] log-decay
    cum = jnp.cumsum(da, axis=2)                          # within-chunk cumsum
    # intra-chunk dual form: L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Q,Q,H]
    ii = jnp.arange(Q)
    tri = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # clamp BEFORE exp: masked (i < j) entries have seg > 0 and would
    # overflow to +inf, which turns into NaN in the backward (inf * 0)
    seg = jnp.where(tri, seg, 0.0)
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    # scores[b,c,i,j,h] = C_i . B_j (broadcast G->H)
    Bh = jnp.repeat(Bc, rep, axis=3)                      # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)
    w = cb * Lmat * dtc[:, :, None, :, :]                 # weight by dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)
    # chunk-final states: S_c = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # [B,nc,Q,H]
    sloc = jnp.einsum(
        "bcjh,bcjhn,bcjhp->bchnp", decay_to_end * dtc, Bh, xc
    )                                                     # [B,nc,H,N,P]
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # [B,nc,H]

    def scan_fn(s_prev, inp):
        sl, cd = inp                                      # [B,H,N,P], [B,H]
        s_new = s_prev * cd[:, :, None, None] + sl
        return s_new, s_prev

    s0 = jnp.zeros((Bsz, H, N, Pd), x.dtype)
    _, s_prevs = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(sloc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                 # [B,nc,H,N,P] state entering chunk
    # inter-chunk: y_i += C_i . (exp(cum_i) * S_prev)
    decay_from_start = jnp.exp(cum)                       # [B,nc,Q,H]
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Ch, s_prevs) * decay_from_start[..., None]
    y = (y_intra + y_inter).reshape(Bsz, nc * Q, H, Pd)
    y = y + x.reshape(Bsz, nc * Q, H, Pd) * D[None, None, :, None]
    return y[:, :S] if pad else y


def ssm_block(p, x, cfg: ModelConfig, conv_state=None, ssd_state=None, pos=None):
    """Full-sequence Mamba2 block.  x: [B, S, d] -> [B, S, d].

    If conv_state/ssd_state given (decode), S must be 1 and the recurrent
    path is used; returns (y, new_conv_state, new_ssd_state).
    """
    s = cfg.ssm
    B, S, d = x.shape
    proj = x @ p["in_proj"]
    z, xbc, dt, d_in, nh, gs = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # [B,S,H]
    if conv_state is None:
        xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xs, B_, C_ = jnp.split(xbc, [d_in, d_in + gs], axis=-1)
        xh = xs.reshape(B, S, nh, s.head_dim)
        Bh = B_.reshape(B, S, s.n_groups, s.d_state)
        Ch = C_.reshape(B, S, s.n_groups, s.d_state)
        y = ssd_chunked(
            xh.astype(jnp.float32), Bh.astype(jnp.float32),
            Ch.astype(jnp.float32), dt, p["A_log"], p["D"], s.chunk
        )
        y = y.reshape(B, S, d_in).astype(x.dtype)
        y = y * jax.nn.silu(z)
        y = rms_norm(y, p["out_norm"], cfg.norm_eps)
        return y @ p["out_proj"]
    else:
        xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
        xs, B_, C_ = jnp.split(xbc, [d_in, d_in + gs], axis=-1)
        xh = xs.reshape(B, nh, s.head_dim).astype(jnp.float32)     # S == 1
        Bh = B_.reshape(B, s.n_groups, s.d_state).astype(jnp.float32)
        Ch = C_.reshape(B, s.n_groups, s.d_state).astype(jnp.float32)
        rep = nh // s.n_groups
        Bh = jnp.repeat(Bh, rep, axis=1)                           # [B,H,N]
        Ch = jnp.repeat(Ch, rep, axis=1)
        A = -jnp.exp(p["A_log"])
        dt1 = dt[:, 0]                                             # [B,H]
        a = jnp.exp(dt1 * A)                                       # [B,H]
        # S' = a S + dt B x^T ; y = C . S' + D x
        upd = dt1[..., None, None] * Bh[..., :, None] * xh[..., None, :]
        new_state = ssd_state * a[..., None, None] + upd           # [B,H,N,P]
        y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
        y = y + xh * p["D"][None, :, None]
        y = y.reshape(B, 1, d_in).astype(x.dtype)
        y = y * jax.nn.silu(z)
        y = rms_norm(y, p["out_norm"], cfg.norm_eps)
        return y @ p["out_proj"], new_conv, new_state


def ssd_reference(x, B_, C_, dt, A_log, D):
    """O(S) sequential oracle for ssd_chunked (tests)."""
    Bsz, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    A = -jnp.exp(A_log)
    Bh = jnp.repeat(B_, rep, axis=2)
    Ch = jnp.repeat(C_, rep, axis=2)

    def step(s, inp):
        xt, bt, ct, dtt = inp
        a = jnp.exp(dtt * A)                                       # [B,H]
        s = s * a[..., None, None] + dtt[..., None, None] * (
            bt[..., :, None] * xt[..., None, :]
        )
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((Bsz, H, N, Pd), x.dtype)
    _, ys = jax.lax.scan(
        step,
        s0,
        (
            jnp.moveaxis(x, 1, 0),
            jnp.moveaxis(Bh, 1, 0),
            jnp.moveaxis(Ch, 1, 0),
            jnp.moveaxis(dt, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1)
    return y + x * D[None, None, :, None]
