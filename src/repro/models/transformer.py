"""Unified LM assembly for every assigned architecture family.

A model is a repeated *super-block pattern* scanned over R repeats:

  dense        ['attn']            x n_layers
  moe          ['attn_moe']        x n_layers     (deepseek-moe)
  moe + MLA    ['mla_moe']         x n_layers     (deepseek-v3)
  ssm          ['ssm']             x n_layers     (mamba2)
  hybrid       ['ssm']*6 + shared-attn call       (zamba2: one SHARED
               weight set applied after every 6 mamba layers)
  vlm          ['attn']*4 + ['xattn']             (llama-3.2-vision:
               cross-attn to stub image embeddings every 5th layer)
  encdec       encoder ['enc'] x encoder_layers;
               decoder ['dec'] (self-attn + cross-attn) x n_layers
               (whisper: stub conv frontend provides audio embeddings)

Parameters for each pattern position are stacked over R and consumed by
`lax.scan` (compact HLO: one lowered block per pattern position
regardless of depth -- essential for 61-layer dry-runs on a CPU host).
Caches are likewise stacked [R, ...] and scanned.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    cross_attn_apply,
    cross_attn_init,
    gqa_decode,
    gqa_full,
    gqa_init,
    mla_decode,
    mla_full,
    mla_init,
)
from .common import ModelConfig
from .layers import (
    chunked_softmax_xent,
    cross_entropy,
    embed_apply,
    embed_init,
    gelu_mlp_apply,
    gelu_mlp_init,
    init_rms_norm,
    rms_norm,
    swiglu_apply,
    swiglu_init,
    unembed_apply,
)
from .moe import moe_apply, moe_init
from .ssm import ssm_block, ssm_init
from . import hints


# ------------------------------------------------------------- patterns


def layer_pattern(cfg: ModelConfig) -> Tuple[List[str], int, bool]:
    """Returns (pattern, repeats, has_shared_block)."""
    if cfg.family == "dense":
        return ["attn"], cfg.n_layers, False
    if cfg.family == "moe":
        typ = "mla_moe" if cfg.mla is not None else "attn_moe"
        return [typ], cfg.n_layers, False
    if cfg.family == "ssm":
        return ["ssm"], cfg.n_layers, False
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every or 6
        assert cfg.n_layers % k == 0, "hybrid layers must divide shared_attn_every"
        return ["ssm"] * k, cfg.n_layers // k, True
    if cfg.family == "vlm":
        k = cfg.cross_attn_every or 5
        assert cfg.n_layers % k == 0
        return ["attn"] * (k - 1) + ["xattn"], cfg.n_layers // k, False
    if cfg.family == "encdec":
        return ["dec"], cfg.n_layers, False
    raise ValueError(cfg.family)


# ---------------------------------------------------------------- init


def _layer_init(key, typ: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if typ == "attn":
        return {
            "ln1": init_rms_norm(d),
            "attn": gqa_init(ks[0], cfg, dtype),
            "ln2": init_rms_norm(d),
            "mlp": swiglu_init(ks[1], d, cfg.d_ff, dtype),
        }
    if typ == "attn_moe":
        return {
            "ln1": init_rms_norm(d),
            "attn": gqa_init(ks[0], cfg, dtype),
            "ln2": init_rms_norm(d),
            "moe": moe_init(ks[1], cfg, dtype),
        }
    if typ == "mla_moe":
        return {
            "ln1": init_rms_norm(d),
            "attn": mla_init(ks[0], cfg, dtype),
            "ln2": init_rms_norm(d),
            "moe": moe_init(ks[1], cfg, dtype),
        }
    if typ == "ssm":
        return {"ln1": init_rms_norm(d), "ssm": ssm_init(ks[0], cfg, dtype)}
    if typ == "xattn":
        return {
            "ln1": init_rms_norm(d),
            "xattn": cross_attn_init(ks[0], cfg, dtype),
            "gate": jnp.zeros((1,), jnp.float32),
            "ln2": init_rms_norm(d),
            "mlp": swiglu_init(ks[1], d, cfg.d_ff, dtype),
        }
    if typ == "enc":
        return {
            "ln1": init_rms_norm(d),
            "attn": gqa_init(ks[0], cfg, dtype),
            "ln2": init_rms_norm(d),
            "mlp": gelu_mlp_init(ks[1], d, cfg.d_ff, dtype),
        }
    if typ == "dec":
        return {
            "ln1": init_rms_norm(d),
            "attn": gqa_init(ks[0], cfg, dtype),
            "lnx": init_rms_norm(d),
            "xattn": cross_attn_init(ks[1], cfg, dtype),
            "ln2": init_rms_norm(d),
            "mlp": gelu_mlp_init(ks[2], d, cfg.d_ff, dtype),
        }
    raise ValueError(typ)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    """Full parameter pytree.  Pattern-position params are stacked over R
    (vmapped init) so the forward pass can scan them."""
    dtype = cfg.jdtype
    pattern, R, shared = layer_pattern(cfg)
    keys = jax.random.split(key, 8 + len(pattern))
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "ln_f": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[1], cfg.vocab, cfg.d_model, dtype)
    for i, typ in enumerate(pattern):
        lk = jax.random.split(keys[2 + i], R)
        params[f"pos{i}"] = jax.vmap(
            lambda k: _layer_init(k, typ, cfg, dtype)
        )(lk)
    if shared:
        params["shared_attn"] = _layer_init(keys[-3], "attn", cfg, dtype)
    if cfg.family == "encdec":
        ek = jax.random.split(keys[-2], cfg.encoder_layers)
        params["enc"] = jax.vmap(lambda k: _layer_init(k, "enc", cfg, dtype))(ek)
        params["enc_ln_f"] = init_rms_norm(cfg.d_model)
    if cfg.family == "vlm":
        params["img_proj"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.d_model), jnp.float32) * 0.02
        ).astype(dtype)
    if cfg.mtp:
        params["mtp"] = _layer_init(keys[-1], "attn", cfg, dtype)
        params["mtp_proj"] = (
            jax.random.normal(keys[-1], (2 * cfg.d_model, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(dtype)
    return params


# ------------------------------------------------------------- forward


def _apply_layer(typ, p, x, cfg, positions, memory, aux_sum):
    if typ in ("attn", "enc"):
        h, _ = gqa_full(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                        positions, causal=(typ == "attn"))
        x = x + h
        mlp = gelu_mlp_apply if typ == "enc" else swiglu_apply
        x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, aux_sum
    if typ == "attn_moe":
        h, _ = gqa_full(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, positions)
        x = x + h
        h, aux = moe_apply(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x + h, aux_sum + aux
    if typ == "mla_moe":
        h, _ = mla_full(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, positions)
        x = x + h
        h, aux = moe_apply(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x + h, aux_sum + aux
    if typ == "ssm":
        x = x + ssm_block(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        return x, aux_sum
    if typ == "xattn":
        h = cross_attn_apply(p["xattn"], rms_norm(x, p["ln1"], cfg.norm_eps), memory, cfg)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * h
        x = x + swiglu_apply(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, aux_sum
    if typ == "dec":
        h, _ = gqa_full(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, positions)
        x = x + h
        x = x + cross_attn_apply(p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps), memory, cfg)
        x = x + gelu_mlp_apply(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, aux_sum
    raise ValueError(typ)


def encode_memory(params, cfg: ModelConfig, memory_embeds):
    """Memory as the decoder sees it: encdec runs the encoder stack; vlm
    memory is projected per-call (cheap).  Serve engines must store THIS
    in the decode cache, not the raw frontend embeddings."""
    if cfg.family == "encdec":
        return _encode(params, cfg, memory_embeds)
    return memory_embeds


def _encode(params, cfg, audio_embeds):
    """Whisper encoder over stub frame embeddings [B, T, d]."""
    x = audio_embeds.astype(cfg.jdtype)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(x, p):
        x, _ = _apply_layer("enc", p, x, cfg, positions, None, 0.0)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def forward_hidden(params, cfg: ModelConfig, tokens, *, memory_embeds=None,
                   remat: str = "none") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Backbone forward: tokens [B, S] -> (hidden [B, S, d], aux_loss).

    memory_embeds: stub frontend output -- image patch embeddings (vlm)
    or audio frame embeddings (encdec)."""
    pattern, R, shared = layer_pattern(cfg)
    B, S = tokens.shape
    x = embed_apply(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    memory = None
    if cfg.family == "vlm":
        memory = memory_embeds.astype(cfg.jdtype) @ params["img_proj"]
    elif cfg.family == "encdec":
        memory = _encode(params, cfg, memory_embeds)

    def super_block(carry, xs):
        x, aux = carry
        for i, typ in enumerate(pattern):
            x = hints.constrain(x, "hidden")
            x, aux = _apply_layer(typ, xs[f"pos{i}"], x, cfg, positions, memory, aux)
        if shared:
            x, aux = _apply_layer("attn", params["shared_attn"], x, cfg,
                                  positions, None, aux)
        return (hints.constrain(x, "hidden"), aux), None

    if remat == "full":
        super_block = jax.checkpoint(super_block, prevent_cse=False)
    elif remat == "dots":
        super_block = jax.checkpoint(
            super_block,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    xs = {f"pos{i}": params[f"pos{i}"] for i in range(len(pattern))}
    (x, aux), _ = jax.lax.scan(super_block, (x, jnp.float32(0)), xs)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, aux


def forward(params, cfg: ModelConfig, tokens, *, memory_embeds=None,
            remat: str = "none") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward: tokens [B, S] -> (logits [B, S, V], aux_loss)."""
    x, aux = forward_hidden(
        params, cfg, tokens, memory_embeds=memory_embeds, remat=remat
    )
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_apply(table, x), aux


def loss_fn(params, cfg: ModelConfig, batch, remat: str = "none"):
    hidden, aux = forward_hidden(
        params, cfg, batch["tokens"],
        memory_embeds=batch.get("memory_embeds"), remat=remat,
    )
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    loss = chunked_softmax_xent(hidden, table, batch["labels"])
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp:
        # DeepSeek-V3 style multi-token prediction (depth 1): combine the
        # backbone hidden with the embedding of the *next* token, run one
        # extra attention block, and predict token t+2.
        B, S = batch["tokens"].shape
        next_tok = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        emb_next = embed_apply(params["embed"], next_tok)
        h_in = jnp.concatenate(
            [rms_norm(hidden, params["ln_f"], cfg.norm_eps),
             rms_norm(emb_next, params["ln_f"], cfg.norm_eps)], axis=-1
        ) @ params["mtp_proj"]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h_mtp, _ = _apply_layer("attn", params["mtp"], h_in, cfg, positions, None, 0.0)
        labels_mtp = jnp.pad(
            batch["labels"][:, 1:], ((0, 0), (0, 1)), constant_values=-100
        )
        mtp_loss = chunked_softmax_xent(h_mtp, table, labels_mtp)
        loss = loss + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    return loss + 0.01 * aux, metrics


# ---------------------------------------------------------------- cache


def init_cache(cfg: ModelConfig, batch: int, seq: int, memory=None):
    """Decode cache pytree, stacked [R, ...] per pattern position."""
    pattern, R, shared = layer_pattern(cfg)
    dtype = cfg.jdtype
    s = cfg.ssm
    # per-slot positions: continuous batching (each batch slot decodes at
    # its own sequence offset)
    cache: Dict[str, Any] = {"pos_idx": jnp.zeros((batch,), jnp.int32)}
    for i, typ in enumerate(pattern):
        if typ in ("attn", "enc", "dec"):
            cache[f"pos{i}_k"] = jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
            cache[f"pos{i}_v"] = jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
        elif typ == "attn_moe":
            cache[f"pos{i}_k"] = jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
            cache[f"pos{i}_v"] = jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
        elif typ == "mla_moe":
            m = cfg.mla
            cache[f"pos{i}_ckv"] = jnp.zeros((R, batch, seq, m.kv_lora_rank), dtype)
            cache[f"pos{i}_kr"] = jnp.zeros((R, batch, seq, m.qk_rope_dim), dtype)
        elif typ == "ssm":
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            cch = d_in + 2 * s.n_groups * s.d_state
            cache[f"pos{i}_conv"] = jnp.zeros((R, batch, s.d_conv - 1, cch), dtype)
            cache[f"pos{i}_ssd"] = jnp.zeros((R, batch, nh, s.d_state, s.head_dim), jnp.float32)
        elif typ == "xattn":
            pass  # memory is static, stored once below
    if shared:
        cache["shared_k"] = jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
        cache["shared_v"] = jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
    if memory is not None:
        cache["memory"] = memory
    return cache


def _decode_layer(typ, p, x, cfg, cache_slice, pos, memory):
    """One-token decode through one layer; returns (x, new_cache_slice)."""
    new = {}
    if typ in ("attn", "attn_moe", "enc", "dec"):
        h, ck, cv = gqa_decode(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
            cache_slice["k"], cache_slice["v"], pos,
        )
        new["k"], new["v"] = ck, cv
        x = x + h
        if typ == "dec":
            x = x + cross_attn_apply(p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps), memory, cfg)
        if typ == "attn_moe":
            h, _ = moe_apply(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
            x = x + h
        else:
            mlp = gelu_mlp_apply if typ in ("enc", "dec") else swiglu_apply
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, new
    if typ == "mla_moe":
        h, ckv, ckr = mla_decode(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
            cache_slice["ckv"], cache_slice["kr"], pos,
        )
        new["ckv"], new["kr"] = ckv, ckr
        x = x + h
        h, _ = moe_apply(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x + h, new
    if typ == "ssm":
        y, conv, ssd = ssm_block(
            p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
            conv_state=cache_slice["conv"], ssd_state=cache_slice["ssd"], pos=pos,
        )
        new["conv"], new["ssd"] = conv, ssd
        return x + y, new
    if typ == "xattn":
        h = cross_attn_apply(p["xattn"], rms_norm(x, p["ln1"], cfg.norm_eps), memory, cfg)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * h
        x = x + swiglu_apply(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, new
    raise ValueError(typ)


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """One decoding step.  tokens: [B, 1]; returns (logits [B,1,V], cache)."""
    pattern, R, shared = layer_pattern(cfg)
    pos = cache["pos_idx"]
    x = embed_apply(params["embed"], tokens)
    memory = cache.get("memory")
    if memory is not None and cfg.family == "vlm":
        memory = memory.astype(cfg.jdtype) @ params["img_proj"]

    def super_block(carry, xs):
        x = carry
        new_sl = {}
        for i, typ in enumerate(pattern):
            sl = {
                key.split("_", 1)[1]: val
                for key, val in xs.items()
                if key.startswith(f"pos{i}_")
            }
            x, new = _decode_layer(typ, xs[f"pos{i}"], x, cfg, sl, pos, memory)
            for key, val in new.items():
                new_sl[f"pos{i}_{key}"] = val
        if shared:
            h, ck, cv = gqa_decode(
                params["shared_attn"]["attn"],
                rms_norm(x, params["shared_attn"]["ln1"], cfg.norm_eps),
                cfg, xs["shared_k"], xs["shared_v"], pos,
            )
            x = x + h
            x = x + swiglu_apply(
                params["shared_attn"]["mlp"],
                rms_norm(x, params["shared_attn"]["ln2"], cfg.norm_eps),
            )
            new_sl["shared_k"], new_sl["shared_v"] = ck, cv
        return x, new_sl

    xs = {f"pos{i}": params[f"pos{i}"] for i in range(len(pattern))}
    for key in cache:
        if key.startswith("pos") and "_" in key and key != "pos_idx":
            xs[key] = cache[key]
        if key.startswith("shared_"):
            xs[key] = cache[key]
    x, new_caches = jax.lax.scan(super_block, x, xs)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed_apply(table, x)
    out_cache = dict(cache)
    out_cache.update(new_caches)
    out_cache["pos_idx"] = pos + 1
    return logits, out_cache


def prefill(params, cfg: ModelConfig, tokens, *, memory_embeds=None):
    """Prefill: full backbone forward, unembed ONLY the last position
    (avoids materializing [B, S, V] logits for 32k prompts)."""
    hidden, _ = forward_hidden(params, cfg, tokens, memory_embeds=memory_embeds)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_apply(table, hidden[:, -1:])
