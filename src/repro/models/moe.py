"""Mixture-of-Experts block: top-k routing with capacity-based dispatch.

Dispatch is scatter-based (not dense one-hot einsum) so the compiled
FLOPs are proportional to *active* parameters -- essential for an honest
MoE roofline.  Experts live on the 'model' mesh axis (expert parallelism):
the token buffer [E, C, d] carries a sharding constraint on E, the expert
matmuls are fully local, and the combine is a weighted gather (GSPMD
inserts the reduce over the model axis, which is the same psum a TP FFN
needs).  Shared experts (DeepSeek-style) are plain SwiGLU MLPs applied to
every token.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import dense_init

try:  # sharding constraint helper (no-op outside jit/mesh contexts)
    from jax.sharding import PartitionSpec as P

    def _constrain(x, spec):
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            return x
except Exception:  # pragma: no cover
    def _constrain(x, spec):
        return x


_DEFAULT_EP_SPEC = None


def set_default_ep_spec(spec):
    """Expert-parallel sharding hint for the [E, C, d] dispatch buffer
    (set by the launcher; None disables the constraint)."""
    global _DEFAULT_EP_SPEC
    _DEFAULT_EP_SPEC = spec


def moe_init(key, cfg: ModelConfig, dtype):
    mo = cfg.moe
    d, f = cfg.d_model, mo.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, mo.n_experts, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (mo.n_experts, d, f), jnp.float32) / d**0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (mo.n_experts, d, f), jnp.float32) / d**0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (mo.n_experts, f, d), jnp.float32) / f**0.5).astype(dtype),
    }
    if mo.n_shared:
        kg, ku, kd = jax.random.split(ks[4], 3)
        fs = f * mo.n_shared
        p["shared"] = {
            "w_gate": dense_init(kg, d, fs, dtype),
            "w_up": dense_init(ku, d, fs, dtype),
            "w_down": dense_init(kd, fs, d, dtype),
        }
    return p


def moe_apply(p, x, cfg: ModelConfig, ep_spec: Optional[object] = None):
    """x: [B, S, d] -> [B, S, d].  Returns (out, aux_loss)."""
    mo = cfg.moe
    if ep_spec is None:
        ep_spec = _DEFAULT_EP_SPEC
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, K = mo.n_experts, mo.top_k
    C = max(1, int(T * K * mo.capacity_factor / E))

    logits = (xt.astype(jnp.float32) @ p["router"])           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)           # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # position of each (t, k) slot within its expert, sort-based: O(T*K)
    # memory (a [T*K, E] one-hot cumsum would be 30+ GB at deepseek scale)
    flat_e = expert_idx.reshape(-1)                            # [T*K]
    TK = flat_e.shape[0]
    order = jnp.argsort(flat_e)                                # stable
    se = flat_e[order]
    first = jnp.searchsorted(se, jnp.arange(E), side="left")   # [E]
    pos_sorted = jnp.arange(TK) - first[se]
    pos = jnp.zeros((TK,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C

    # scatter tokens into the expert buffer [E, C, d] (drop on overflow)
    xe = jnp.repeat(xt, K, axis=0)                             # [T*K, d]
    safe_pos = jnp.where(keep, pos, C - 1)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], xe, 0).astype(x.dtype), mode="drop"
    )
    if ep_spec is not None:
        buf = _constrain(buf, ep_spec)

    # expert SwiGLU, batched over E (local under EP sharding of dim 0)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])         # [E, C, d]
    if ep_spec is not None:
        y = _constrain(y, ep_spec)

    # combine: gather each slot's output, weight by its gate
    ye = y[flat_e, safe_pos]                                   # [T*K, d]
    ye = ye * (gate_vals.reshape(-1)[:, None] * keep[:, None]).astype(y.dtype)
    out = ye.reshape(T, K, d).sum(axis=1)

    if mo.n_shared:
        sh = p["shared"]
        g = jax.nn.silu(xt @ sh["w_gate"])
        u = xt @ sh["w_up"]
        out = out + (g * u) @ sh["w_down"]
    return out.reshape(B, S, d).astype(x.dtype), aux
