"""Launcher-set sharding hints for activations (no-op when unset).

Keeps model code mesh-agnostic: the launcher (dryrun/train) sets the
PartitionSpecs once; `constrain` applies them inside jit when a mesh
context is active, and silently no-ops otherwise (CPU tests).
"""

from __future__ import annotations

import jax

_SPECS = {}


def set_hint(name: str, spec):
    _SPECS[name] = spec


def clear_hints():
    _SPECS.clear()


def constrain(x, name: str):
    spec = _SPECS.get(name)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
