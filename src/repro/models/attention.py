"""Attention blocks: GQA (RoPE, causal, sliding-window, cross), MLA.

The full-sequence paths use a blocked online-softmax formulation (pure
jnp `lax.scan` over KV chunks, unrolled over Q chunks with a static
lower-triangular chunk skip for causal masks).  This is simultaneously:
  * the memory-sane lowering for 32k prefill (never materializes S x S),
  * the oracle that kernels/flash_attention (Pallas) must match,
  * FLOP-faithful for the roofline (causal chunk-skip avoids counting
    the upper triangle twice).

Decode paths attend a fixed-size cache with position-validity masks.
MLA keeps the compressed c_kv cache and uses the absorbed formulation
for decode (q is folded through W_uk so scores are computed in latent
space), the TPU-friendly form of DeepSeek's MLA.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import MLAConfig, ModelConfig
from .layers import apply_rope, dense_init, init_rms_norm, rms_norm

NEG_INF = -1e30


# ------------------------------------------------------------------ GQA


def gqa_init(key, cfg: ModelConfig, dtype):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    # keep attention head-sharded (never head-dim-sharded: a sharded hd
    # contraction turns every score block into an all-reduce)
    from . import hints
    q = hints.constrain(q, "attn_q")
    k = hints.constrain(k, "attn_kv")
    v = hints.constrain(v, "attn_kv")
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunk_bounds(qi, q_chunk, kv_chunk, n_kv, causal, window, q_offset):
    """Static [lo, hi) kv-chunk range visited by q chunk qi."""
    if causal:
        last_q = q_offset + (qi + 1) * q_chunk - 1
        hi = min(n_kv, last_q // kv_chunk + 1)
    else:
        hi = n_kv
    if window is not None and causal:
        first_q = q_offset + qi * q_chunk
        lo = max(0, (first_q - window + 1) // kv_chunk)
    else:
        lo = 0
    return lo, max(hi, lo + 1)


def _mask_for(q_pos, kv_pos, causal, window, Skv_true):
    mask = (kv_pos[None, :] <= q_pos[:, None]) if causal else jnp.ones(
        (q_pos.shape[0], kv_pos.shape[0]), bool
    )
    if window is not None and causal:
        mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
    return mask & (kv_pos < Skv_true)[None, :]


def _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk):
    """Online-softmax forward.  Returns (out [B,Sq,H,hd_v],
    lse [n_q, B, Hkv, rep, qc]).  Peak memory O(chunk^2), not O(S^2)."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    n_q = -(-Sq // q_chunk)
    n_kv = -(-Skv // kv_chunk)
    qp = jnp.pad(q, ((0, 0), (0, n_q * q_chunk - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, n_kv * kv_chunk - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, n_kv * kv_chunk - Skv), (0, 0), (0, 0)))
    kc = kp.reshape(B, n_kv, kv_chunk, Hkv, hd)
    vc = vp.reshape(B, n_kv, kv_chunk, Hkv, hd_v)

    outs, lses = [], []
    for qi in range(n_q):
        qb = qp[:, qi * q_chunk : (qi + 1) * q_chunk].reshape(
            B, q_chunk, Hkv, rep, hd
        )
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        lo, hi = _chunk_bounds(qi, q_chunk, kv_chunk, n_kv, causal, window, q_offset)

        def step(carry, blk):
            m, l, acc = carry
            kb, vb, kv_start = blk
            kv_pos = kv_start + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask_for(q_pos, kv_pos, causal, window, Skv)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pz = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pz.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhrqk,bkhd->bhrqd", pz, vb, preferred_element_type=jnp.float32
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, rep, q_chunk, hd_v), jnp.float32)
        ks = jnp.moveaxis(kc[:, lo:hi], 1, 0)
        vs = jnp.moveaxis(vc[:, lo:hi], 1, 0)
        starts = (jnp.arange(lo, hi) * kv_chunk).astype(jnp.int32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, starts))
        safe_l = jnp.maximum(l, 1e-30)
        ob = (acc / safe_l[..., None]).astype(q.dtype)
        outs.append(jnp.moveaxis(ob, 3, 1).reshape(B, q_chunk, H, hd_v))
        lses.append(m + jnp.log(safe_l))                  # [B,Hkv,rep,qc]
    out = jnp.concatenate(outs, axis=1)[:, :Sq]
    return out, jnp.stack(lses)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def blocked_attention(q, k, v, causal, window=None, q_offset=0,
                      q_chunk=1024, kv_chunk=1024):
    """Flash-style blocked attention with an O(S)-memory custom VJP.

    q: [B, Sq, H, hd]; k/v: [B, Skv, Hkv, hd(_v)]; GQA head h attends kv
    head h // (H // Hkv).  Causal: q position i sees kv j iff
    j <= i + q_offset (and i + q_offset - j < window for SWA).  The
    backward pass recomputes scores chunk-by-chunk from the saved
    (q, k, v, o, lse) -- the flash-attention recipe, and the oracle the
    Pallas kernel must match.
    """
    q_chunk = min(q_chunk, q.shape[1])
    kv_chunk = min(kv_chunk, k.shape[1])
    out, _ = _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk)
    return out


def _ba_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk):
    q_chunk = min(q_chunk, q.shape[1])
    kv_chunk = min(kv_chunk, k.shape[1])
    out, lse = _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _ba_bwd(causal, window, q_offset, q_chunk, kv_chunk, res, do):
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    n_q = -(-Sq // q_chunk)
    n_kv = -(-Skv // kv_chunk)
    qp = jnp.pad(q, ((0, 0), (0, n_q * q_chunk - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, n_kv * kv_chunk - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, n_kv * kv_chunk - Skv), (0, 0), (0, 0)))
    dop = jnp.pad(do, ((0, 0), (0, n_q * q_chunk - Sq), (0, 0), (0, 0)))
    op = jnp.pad(o, ((0, 0), (0, n_q * q_chunk - Sq), (0, 0), (0, 0)))
    kc = kp.reshape(B, n_kv, kv_chunk, Hkv, hd)
    vc = vp.reshape(B, n_kv, kv_chunk, Hkv, hd_v)

    dq = jnp.zeros((B, n_q * q_chunk, Hkv, rep, hd), jnp.float32)
    dk = jnp.zeros((B, n_kv, kv_chunk, Hkv, hd), jnp.float32)
    dv = jnp.zeros((B, n_kv, kv_chunk, Hkv, hd_v), jnp.float32)

    for qi in range(n_q):
        sl = slice(qi * q_chunk, (qi + 1) * q_chunk)
        qb = qp[:, sl].reshape(B, q_chunk, Hkv, rep, hd)
        dob = dop[:, sl].reshape(B, q_chunk, Hkv, rep, hd_v)
        ob = op[:, sl].reshape(B, q_chunk, Hkv, rep, hd_v)
        lse_i = lse[qi]                                     # [B,Hkv,rep,qc]
        # D = rowsum(do * o)
        Dc = jnp.einsum("bqhrd,bqhrd->bhrq", dob.astype(jnp.float32),
                        ob.astype(jnp.float32))
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        lo, hi = _chunk_bounds(qi, q_chunk, kv_chunk, n_kv, causal, window, q_offset)

        def step(carry, blk):
            dq_i, dk_all, dv_all = carry
            kb, vb, j = blk                                 # j: kv chunk idx
            kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask_for(q_pos, kv_pos, causal, window, Skv)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])               # [B,Hkv,rep,qc,kc]
            dpv = jnp.einsum("bqhrd,bkhd->bhrqk", dob.astype(jnp.float32), vb,
                             preferred_element_type=jnp.float32)
            ds = p * (dpv - Dc[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhrqk,bkhd->bqhrd", ds, kb,
                                     preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bhrqk,bqhrd->bkhd", ds, qb.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            dv_j = jnp.einsum("bhrqk,bqhrd->bkhd", p, dob.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            dk_all = jax.lax.dynamic_update_index_in_dim(
                dk_all, jax.lax.dynamic_index_in_dim(dk_all, j, 1, keepdims=False) + dk_j,
                j, 1)
            dv_all = jax.lax.dynamic_update_index_in_dim(
                dv_all, jax.lax.dynamic_index_in_dim(dv_all, j, 1, keepdims=False) + dv_j,
                j, 1)
            return (dq_i, dk_all, dv_all), None

        dq_i0 = jnp.zeros((B, q_chunk, Hkv, rep, hd), jnp.float32)
        ks = jnp.moveaxis(kc[:, lo:hi], 1, 0)
        vs = jnp.moveaxis(vc[:, lo:hi], 1, 0)
        idxs = jnp.arange(lo, hi, dtype=jnp.int32)
        (dq_i, dk, dv), _ = jax.lax.scan(step, (dq_i0, dk, dv), (ks, vs, idxs))
        dq = jax.lax.dynamic_update_slice_in_dim(dq, dq_i, qi * q_chunk, axis=1)

    dq = dq.reshape(B, n_q * q_chunk, H, hd)[:, :Sq].astype(q.dtype)
    dk = dk.reshape(B, n_kv * kv_chunk, Hkv, hd)[:, :Skv].astype(k.dtype)
    dv = dv.reshape(B, n_kv * kv_chunk, Hkv, hd_v)[:, :Skv].astype(v.dtype)
    return dq, dk, dv


blocked_attention.defvjp(_ba_fwd, _ba_bwd)


def gqa_full(p, x, cfg: ModelConfig, positions, *, causal=True):
    """Train/prefill self-attention; returns ([B,S,d], (k, v) for caching)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = blocked_attention(q, k, v, causal, cfg.sliding_window)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    return o, (k, v)


def gqa_decode(p, x, cfg: ModelConfig, cache_k, cache_v, pos):
    """One-token decode.  x: [B, 1, d]; cache_[kv]: [B, S, Hkv, hd];
    pos: [B] int32 per-slot positions (continuous batching) or scalar.
    Returns (out, cache_k, cache_v).
    """
    B = x.shape[0]
    hd = cfg.hd
    S = cache_k.shape[1]
    pos = jnp.broadcast_to(pos, (B,))
    positions = pos[:, None]
    q, k, v = _project_qkv(p, x, cfg, positions)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, pos].set(v[:, 0].astype(cache_v.dtype))
    rep = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(B, 1, cfg.n_kv_heads, rep, hd)
    s = jnp.einsum(
        "bqhrd,bkhd->bhrqk", qh, cache_k, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    idx = jnp.arange(S)
    mask = idx[None, :] <= pos[:, None]                     # [B, S]
    if cfg.sliding_window is not None:
        mask = mask & (pos[:, None] - idx[None, :] < cfg.sliding_window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", w, cache_v, preferred_element_type=jnp.float32)
    o = o.astype(x.dtype).reshape(B, 1, cfg.n_heads * hd) @ p["wo"]
    return o, cache_k, cache_v


def cross_attn_init(key, cfg: ModelConfig, dtype, kv_dim: Optional[int] = None):
    hd = cfg.hd
    kv_dim = kv_dim or cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], kv_dim, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], kv_dim, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }


def cross_attn_apply(p, x, memory, cfg: ModelConfig):
    """x: [B, S, d] queries; memory: [B, T, d_kv] keys/values (no RoPE)."""
    B, S, _ = x.shape
    T = memory.shape[1]
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (memory @ p["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
    v = (memory @ p["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
    o = blocked_attention(q, k, v, False)
    return o.reshape(B, S, cfg.n_heads * hd) @ p["wo"]


# ------------------------------------------------------------------ MLA


def mla_init(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": init_rms_norm(m.q_lora_rank),
        "w_uq": dense_init(ks[1], m.q_lora_rank, H * (m.qk_nope_dim + m.qk_rope_dim), dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "kv_norm": init_rms_norm(m.kv_lora_rank),
        "w_kr": dense_init(ks[3], d, m.qk_rope_dim, dtype),
        "w_uk": dense_init(ks[4], m.kv_lora_rank, H * m.qk_nope_dim, dtype),
        "w_uv": dense_init(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype),
        "wo": dense_init(ks[6], H * m.v_head_dim, d, dtype),
    }


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    qall = (cq @ p["w_uq"]).reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = qall[..., : m.qk_nope_dim], qall[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(p, x, cfg: ModelConfig, positions, *, causal=True):
    """Materialized MLA for train/prefill; returns (out, (c_kv, k_rope))."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)       # [B,S,r]
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, m.qk_nope_dim)
    vfull = (c_kv @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_dim))], axis=-1)
    o = blocked_attention(q, k, vfull, causal)
    o = o.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    return o, (c_kv, k_rope[:, :, 0, :])


def mla_decode(p, x, cfg: ModelConfig, cache_ckv, cache_kr, pos):
    """Absorbed-form MLA decode with the compressed cache.

    cache_ckv: [B, S, r]; cache_kr: [B, S, rope_dim].  Scores are computed
    in latent space: q_lat = q_nope @ W_uk (per head), so per-token work is
    O(H*(nope*r)) + O(S*(r + rope)) instead of materializing K/V.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    S = cache_ckv.shape[1]
    pos = jnp.broadcast_to(pos, (B,))
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)   # [B,1,H,*]
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    bidx = jnp.arange(B)
    cache_ckv = cache_ckv.at[bidx, pos].set(c_kv[:, 0].astype(cache_ckv.dtype))
    cache_kr = cache_kr.at[bidx, pos].set(k_rope[:, 0].astype(cache_kr.dtype))
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)              # absorb W_uk
    s = jnp.einsum("bqhr,bkr->bhqk", q_lat, cache_ckv, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bqhn,bkn->bhqk", q_rope, cache_kr, preferred_element_type=jnp.float32)
    s = s / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    mask = jnp.arange(S)[None, :] <= pos[:, None]           # [B, S]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", w, cache_ckv, preferred_element_type=jnp.float32)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), w_uv)
    o = o.reshape(B, 1, H * m.v_head_dim) @ p["wo"]
    return o, cache_ckv, cache_kr
