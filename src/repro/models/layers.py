"""Primitive layers: norms, MLPs, embeddings, RoPE.  Pure-functional params
as nested dicts; initializers return (params, apply) separation kept simple:
init_* builds params, apply functions take (params, x)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def swiglu_init(key, d: int, f: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, f, dtype),
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype),
    }


def swiglu_apply(p, x):
    g = jax.nn.silu(x @ p["w_gate"])
    u = x @ p["w_up"]
    return (g * u) @ p["w_down"]


def gelu_mlp_init(key, d: int, f: int, dtype):
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, d, f, dtype), "w_out": dense_init(k2, f, d, dtype)}


def gelu_mlp_apply(p, x):
    return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- embeddings


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed_apply(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed_apply(table: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x: [..., d] -> logits [..., vocab]; table: [vocab, d]."""
    return x @ table.T


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, ignore: int = -100):
    """Mean CE over non-ignored positions.  logits [..., V], labels [...]."""
    mask = (labels != ignore).astype(jnp.float32)
    labels = jnp.where(labels == ignore, 0, labels)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_softmax_xent(hidden: jnp.ndarray, table: jnp.ndarray,
                         labels: jnp.ndarray, ignore: int = -100,
                         chunk: int = 512):
    """Memory-sane LM-head cross entropy.

    Never materializes the full [B, S, V] logits: scans over sequence
    chunks, computing each chunk's logits (hidden_chunk @ table.T, kept
    vocab-sharded via the 'logits' hint), reducing to per-chunk nll sums.
    The chunk body is checkpointed so the backward pass recomputes the
    chunk logits instead of saving them -- peak logits memory is
    [B, chunk, V] / model_parallel instead of [B, S, V].
    """
    from . import hints

    B, S, d = hidden.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=ignore)
    hc = jnp.moveaxis(hidden.reshape(B, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, cnt = carry
        h, l = xs                                   # [B, chunk, d], [B, chunk]
        logits = hints.constrain(h @ table.T, "logits")
        mask = (l != ignore).astype(jnp.float32)
        lsafe = jnp.where(l == ignore, 0, l)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), lsafe[..., None], axis=-1
        )[..., 0]
        nll = (lse - gold) * mask
        return (nll_sum + nll.sum(), cnt + mask.sum()), None

    (nll_sum, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), (hc, lc)
    )
    return nll_sum / jnp.maximum(cnt, 1.0)
