"""Deterministic synthetic LM data pipeline: sharded, resumable, prefetched.

Real-cluster properties this reproduces:
  * determinism: batch at step t is a pure function of (seed, step) --
    restart/elastic-resize replays the exact token stream;
  * sharding: each data-parallel rank materializes only its slice;
  * checkpointable state: the iterator state is just the step counter;
  * prefetch: a background thread keeps a small queue of ready batches.

Tokens are Zipf-distributed (vocabulary rank-frequency ~ 1/k) so losses
have realistic structure (a uniform stream makes every model converge to
the same trivial entropy).  Labels are next-token targets with the final
position masked.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1
    memory_tokens: int = 0      # stub frontend length (vlm/encdec)
    d_model: int = 0


class SyntheticLM:
    """Deterministic synthetic token stream."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self.step = 0

    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: Dict):
        self.step = int(state["step"])

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard])
        )
        # Zipf over vocab, clipped; rejection-free via inverse-CDF on ranks
        u = rng.random((self.local_batch, cfg.seq_len))
        ranks = np.floor(
            (u * (cfg.vocab ** (cfg.zipf_a - 1.0) - 1) + 1)
            ** (1.0 / (cfg.zipf_a - 1.0))
        ).astype(np.int64)
        tokens = np.clip(ranks - 1, 0, cfg.vocab - 1).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((self.local_batch, 1), -100, np.int32)], axis=1
        )
        out = {"tokens": tokens, "labels": labels}
        if cfg.memory_tokens:
            out["memory_embeds"] = rng.normal(
                size=(self.local_batch, cfg.memory_tokens, cfg.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self.step)
            self.step += 1
            yield b


class Prefetcher:
    """Background-thread prefetch queue over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.dead = False
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for b in self.it:
                if self.dead:
                    return
                self.q.put(b)
        except Exception as e:  # pragma: no cover
            self.q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self.dead = True
