"""Per-architecture smoke tests: REDUCED same-family configs, one
forward/train step + a few decode steps on CPU; shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.models.common import SHAPES
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

ARCHS = all_arch_names()


def _batch(cfg, B=2, S=24):
    key = jax.random.PRNGKey(0)
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        batch["memory_embeds"] = jnp.ones(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        batch["memory_embeds"] = jnp.ones(
            (B, cfg.n_audio_frames, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: forward(p, cfg, b["tokens"],
                             memory_embeds=b.get("memory_embeds"))
    )(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config(arch, smoke=True)
    tcfg = TrainConfig(microbatches=2, remat="full",
                       opt=AdamWConfig(lr=1e-3, warmup_steps=1))
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, B=4, S=16)
    state, m = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert int(state["opt"]["step"]) == 2
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.sum(jnp.abs(b.astype(jnp.float32)))),
        jax.tree.map(lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32),
                     state["params"], init_train_state(cfg, tcfg,
                                                       jax.random.PRNGKey(2))["params"]),
        0.0,
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(3))
    B, S = 2, 12
    batch = _batch(cfg, B=B)
    cache = init_cache(cfg, B, S, memory=batch.get("memory_embeds"))
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    tok = batch["tokens"][:, :1]
    for i in range(4):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert int(cache["pos_idx"][0]) == 4


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m", "whisper-small"])
def test_prefill_matches_decode(arch):
    """Greedy next-token from prefill == next-token from step-by-step decode."""
    from repro.models.transformer import encode_memory

    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(4))
    B, S = 1, 8
    batch = _batch(cfg, B=B, S=S)
    last = prefill(params, cfg, batch["tokens"],
                   memory_embeds=batch.get("memory_embeds"))
    mem = batch.get("memory_embeds")
    if mem is not None:
        mem = encode_memory(params, cfg, mem)
    cache = init_cache(cfg, B, S + 4, memory=mem)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    for i in range(S):
        logits, cache = step(params, cache, batch["tokens"][:, i : i + 1])
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(logits[:, 0], np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32, d_ff=10240,
                            vocab=32000),
        "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14,
                           n_kv_heads=2, d_ff=4864, vocab=151936),
        "h2o-danube-1.8b": dict(n_layers=24, d_model=2560, n_heads=32,
                                n_kv_heads=8, d_ff=6912, vocab=32000),
        "stablelm-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=13824, vocab=100352),
        "granite-3-2b": dict(n_layers=40, d_model=2048, n_heads=32,
                             n_kv_heads=8, d_ff=8192, vocab=49155),
        "llama-3.2-vision-11b": dict(n_layers=40, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=14336, vocab=128256),
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 vocab=129280),
        "deepseek-moe-16b": dict(n_layers=28, d_model=2048, n_heads=16,
                                 vocab=102400),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280),
        "whisper-small": dict(n_layers=12, d_model=768, n_heads=12,
                              d_ff=3072, vocab=51865, encoder_layers=12),
    }
    for arch, wants in spec.items():
        cfg = get_config(arch)
        for key, val in wants.items():
            assert getattr(cfg, key) == val, (arch, key, getattr(cfg, key), val)
    # MoE / MLA / SSM details
    v3 = get_config("deepseek-v3-671b")
    assert v3.moe.n_experts == 256 and v3.moe.top_k == 8 and v3.moe.n_shared == 1
    assert v3.mla is not None and v3.mtp
    dm = get_config("deepseek-moe-16b")
    assert dm.moe.n_experts == 64 and dm.moe.top_k == 6 and dm.moe.n_shared == 2
    assert get_config("mamba2-780m").ssm.d_state == 128
    assert get_config("zamba2-2.7b").ssm.d_state == 64
    assert get_config("h2o-danube-1.8b").sliding_window == 4096


def test_param_counts_sane():
    """Analytic parameter counts are in the advertised ballpark."""
    expect = {
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "h2o-danube-1.8b": (1.4e9, 2.3e9),
        "stablelm-12b": (10e9, 14e9),
        "granite-3-2b": (2.0e9, 3.6e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "deepseek-v3-671b": (600e9, 750e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "zamba2-2.7b": (2.0e9, 3.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
