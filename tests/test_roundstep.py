"""Backend conformance for the pluggable round-step data plane.

Two layers, both single-process (no multidevice marker -- this is the
schedule-stack fast lane's coverage of the Pallas path):

  1. kernel-level: the fused Pallas kernels (interpret mode) agree
     bit-exactly with the jnp reference backend on random slot plans,
     including the equal-slot pipeline cases, across dtypes and ops;
  2. collective-level: ``simulate_*`` with ``backend=`` executes the
     real round-step data plane over all p ranks and asserts bit-exact
     agreement with the message-passing NumPy reference, over the
     engine-test edge cases (p = 1, powers of two, odd p) for sum/max
     on int and float dtypes.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.roundstep import (
    dataplane_allgather,
    dataplane_broadcast,
    dataplane_reduce,
    get_round_step,
)
from repro.core.simulator import (
    simulate_allbroadcast,
    simulate_allreduce,
    simulate_broadcast,
    simulate_reduce,
)

RNG = np.random.default_rng(7)

# The p=1 / power-of-two / odd edge cases of tests/test_engine.py.
EDGE_PS = [1, 2, 3, 4, 5, 8, 11, 16, 32, 36]
BACKENDS = ["jnp", "pallas"]


def _rand(shape, dtype):
    if np.issubdtype(np.dtype(dtype), np.integer):
        return np.asarray(RNG.integers(-100, 100, size=shape), dtype)
    return np.asarray(RNG.normal(size=shape), dtype)


# ------------------------------------------------------- kernel level


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("R,ns,bs", [(1, 4, 8), (8, 6, 16), (17, 9, 4)])
def test_shuffle_backends_bitexact(dtype, R, ns, bs):
    buf = jnp.asarray(_rand((R, ns, bs), dtype))
    msg = jnp.asarray(_rand((R, bs), dtype))
    recv = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    send = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    # force the pipeline case (send what was just received) on row 0
    send = send.at[0].set(recv[0])
    jstep, pstep = get_round_step("jnp"), get_round_step("pallas")
    jb, jm = jstep.shuffle(buf, msg, recv, send)
    pb, pm = pstep.shuffle(buf, msg, recv, send)
    np.testing.assert_array_equal(np.asarray(jb), np.asarray(pb))
    np.testing.assert_array_equal(np.asarray(jm), np.asarray(pm))
    # pack/unpack primitives agree too
    np.testing.assert_array_equal(
        np.asarray(jstep.pack(buf, send)), np.asarray(pstep.pack(buf, send))
    )
    np.testing.assert_array_equal(
        np.asarray(jstep.unpack(buf, msg, recv)),
        np.asarray(pstep.unpack(buf, msg, recv)),
    )


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("R,ns,bs", [(1, 4, 8), (8, 6, 16)])
def test_acc_shuffle_backends_bitexact(op, dtype, R, ns, bs):
    buf = jnp.asarray(_rand((R, ns, bs), dtype))
    msg = jnp.asarray(_rand((R, bs), dtype))
    acc = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    fwd = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    # force the clamped same-slot case (capture the just-accumulated
    # partial, then drain it) on row 0
    fwd = fwd.at[0].set(acc[0])
    jstep, pstep = get_round_step("jnp"), get_round_step("pallas")
    jb, jm = jstep.acc_shuffle(buf, msg, acc, fwd, op=op)
    pb, pm = pstep.acc_shuffle(buf, msg, acc, fwd, op=op)
    np.testing.assert_array_equal(np.asarray(jb), np.asarray(pb))
    np.testing.assert_array_equal(np.asarray(jm), np.asarray(pm))


def test_acc_shuffle_semantics():
    """The fused step implements accumulate -> capture -> drain."""
    buf = jnp.asarray(np.arange(2 * 3 * 2, dtype=np.int32).reshape(2, 3, 2))
    msg = jnp.asarray(np.full((2, 2), 10, np.int32))
    acc = jnp.asarray([0, 1], jnp.int32)
    fwd = jnp.asarray([0, 2], jnp.int32)
    for backend in BACKENDS:
        nb, out = get_round_step(backend).acc_shuffle(buf, msg, acc, fwd)
        nb, out = np.asarray(nb), np.asarray(out)
        # row 0: acc == fwd -> capture sees the accumulated value, slot drained
        assert np.array_equal(out[0], [0 + 10, 1 + 10])
        assert np.array_equal(nb[0, 0], [0, 0])
        # row 1: accumulate into slot 1, capture+drain slot 2
        assert np.array_equal(nb[1, 1], [8 + 10, 9 + 10])
        assert np.array_equal(out[1], [10, 11])
        assert np.array_equal(nb[1, 2], [0, 0])


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="backend"):
        get_round_step("cuda")


@pytest.mark.parametrize("backend", BACKENDS)
def test_unknown_op_raises(backend):
    """Both backends validate the reduction op instead of silently
    falling back (shared registry: repro.kernels.reduce_ops)."""
    buf = jnp.zeros((2, 3, 4), jnp.float32)
    msg = jnp.zeros((2, 4), jnp.float32)
    idx = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError, match="reduction op"):
        get_round_step(backend).acc_shuffle(buf, msg, idx, idx, op="min")


# --------------------------------------------------- collective level


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", EDGE_PS)
def test_simulate_broadcast_certifies_backend(backend, p):
    for n in (1, 3, 5):
        for root in sorted({0, p - 1}):
            res = simulate_broadcast(p, n, root=root, backend=backend)
            assert res.rounds == res.optimal_rounds
            assert res.backend == backend


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", EDGE_PS)
def test_simulate_reduce_certifies_backend(backend, p):
    """Bit-exact sum/max on int64 and float64 values, every edge p."""
    rng = np.random.default_rng(p)
    for n in (1, 4):
        ivals = rng.integers(-(1 << 31), 1 << 31, size=(p, n)).astype(np.int64)
        fvals = rng.normal(size=(p, n))
        for op, vals in [("+", ivals), ("+", fvals),
                         ("max", ivals), ("max", fvals)]:
            res = simulate_reduce(p, n, root=p - 1, op=op, values=vals,
                                  backend=backend)
            assert res.rounds == res.optimal_rounds


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", [1, 2, 4, 5, 8, 16])
def test_simulate_allreduce_certifies_backend(backend, p):
    rng = np.random.default_rng(p * 3 + 1)
    for n in (1, 4):
        vals = rng.normal(size=(p, n))
        res = simulate_allreduce(p, n, values=vals, backend=backend)
        assert res.rounds == res.optimal_rounds
        ivals = rng.integers(-(1 << 31), 1 << 31, size=(p, n)).astype(np.int64)
        simulate_allreduce(p, n, values=ivals, op="max", backend=backend)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", [1, 2, 4, 8, 11])
def test_simulate_allbroadcast_certifies_backend(backend, p):
    for n in (1, 3):
        res = simulate_allbroadcast(p, n, backend=backend)
        assert res.rounds == res.optimal_rounds


# --------------------------------------- data planes agree across backends


@pytest.mark.parametrize("p", [2, 8, 13])
def test_dataplanes_bitexact_across_backends(p):
    """Beyond certifying each backend against the reference: the two
    backends produce identical buffers on identical inputs (float sums
    included -- same accumulation order)."""
    rng = np.random.default_rng(p)
    n = 4
    bvals = rng.normal(size=(n,))
    assert np.array_equal(dataplane_broadcast(p, n, 0, bvals, "jnp"),
                          dataplane_broadcast(p, n, 0, bvals, "pallas"))
    gvals = rng.normal(size=(p, n))
    assert np.array_equal(dataplane_allgather(p, n, gvals, "jnp"),
                          dataplane_allgather(p, n, gvals, "pallas"))
    for op in ("sum", "max"):
        assert np.array_equal(
            dataplane_reduce(p, n, p - 1, gvals, op, "jnp"),
            dataplane_reduce(p, n, p - 1, gvals, op, "pallas"),
        )
