"""Shared test helpers.

``run_worker`` launches tests/mp_worker.py in a subprocess with a
forced p-device host platform, so the main pytest process keeps its
single-device view (required for the smoke tests).  Both the collective
suite (test_collectives.py) and the communicator suite (test_comm.py)
use it; keeping it here means the invocation protocol (env flags,
SKIP handling, timeout) cannot diverge between them.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKER = os.path.join(ROOT, "tests", "mp_worker.py")


def run_worker(what: str, p: int, backend: str = "jnp"):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run(
        [sys.executable, WORKER, what, str(p), backend],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert res.returncode == 0, f"worker failed:\n{res.stdout}\n{res.stderr}"
    if "SKIP" in res.stdout:
        pytest.skip(res.stdout.strip().splitlines()[-1])
    assert "ALL OK" in res.stdout
