"""Shared test helpers.

``run_worker`` launches tests/mp_worker.py in a subprocess with a
forced p-device host platform, so the main pytest process keeps its
single-device view (required for the smoke tests).  The collective
suite (test_collectives.py), the communicator suite (test_comm.py) and
the hierarchical suite (test_hier.py) use it; keeping it here means the
invocation protocol (env flags, SKIP handling, timeout) cannot diverge
between them.

``_plan_cache_isolation_audit`` is the autouse audit of the engine's
process-wide, eviction-free plan cache: the cache's documented contract
is that entries are immutable and identity-stable for the life of the
process, so a test that *clears* it invalidates every plan identity
other tests may hold.  The fixture fails any test that shrinks the
cache without declaring the ``plan_cache_mutating`` marker -- making
cache-clearing opt-in and visible instead of silent cross-test
pollution.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
WORKER = os.path.join(ROOT, "tests", "mp_worker.py")


def run_worker(what: str, p: int, backend: str = "jnp", *extra: str):
    """Run ``tests/mp_worker.py what p backend *extra`` on a forced
    p-device host platform; map a SKIP line to pytest.skip."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run(
        [sys.executable, WORKER, what, str(p), backend,
         *[str(a) for a in extra]],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert res.returncode == 0, f"worker failed:\n{res.stdout}\n{res.stderr}"
    if "SKIP" in res.stdout:
        pytest.skip(res.stdout.strip().splitlines()[-1])
    assert "ALL OK" in res.stdout


@pytest.fixture(autouse=True)
def _plan_cache_isolation_audit(request):
    """Audit the process-wide plan cache around every test.

    The cache is eviction-free by design; shrinking it mid-suite breaks
    the ``cached_plan`` identity contract for every other test.  Tests
    that legitimately clear it (the cache-management tests themselves)
    declare ``@pytest.mark.plan_cache_mutating`` and must leave the
    stats in a consistent reset state.
    """
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.core.engine import plan_cache_info

    before = plan_cache_info()
    yield
    after = plan_cache_info()
    if request.node.get_closest_marker("plan_cache_mutating") is None:
        assert after["size"] >= before["size"], (
            f"{request.node.nodeid} shrank the process-wide plan cache "
            f"({before['size']} -> {after['size']}) without the "
            f"plan_cache_mutating marker; clearing it breaks the "
            f"cached-plan identity contract for the rest of the suite"
        )
