"""Tests for the paper's core algorithms: skips, baseblock, recv/send schedules.

Anchored on the paper's own artifacts:
  * Table 1 (p=16) and Table 2 (p=17) golden schedules,
  * the four correctness conditions of §2.1 (exhaustive over p ranges),
  * Proposition 1 (<= 2q recursive calls) and Proposition 3 (<= 4
    violations) complexity bounds,
  * Observations 1-4 on the skip structure.
"""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.schedule import (
    baseblock,
    ceil_log2,
    compute_skips,
    recv_schedule,
    schedule_tables,
    send_schedule,
    virtual_rounds,
)
from repro.core.reference import (
    recv_schedule_legacy,
    send_schedule_from_recv,
    send_schedule_legacy,
)
from repro.core.verify import verify_p, verify_schedules


# ---------------------------------------------------------------- skips


@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 100, 1 << 20])
def test_skips_structure(p):
    q = ceil_log2(p)
    skip = compute_skips(p)
    assert len(skip) == q + 1
    assert skip[q] == p
    if p >= 2:
        assert skip[0] == 1 and skip[1] == 2
    # Observation 1: skip[k] + skip[k] >= skip[k+1]
    for k in range(q):
        assert 2 * skip[k] >= skip[k + 1]
        assert skip[k] == skip[k + 1] - skip[k + 1] // 2
    # Observation 4: 1 + sum_{i<k} skip[i] >= skip[k]; sum_{i<=k-2} < skip[k]
    for k in range(q):
        assert 1 + sum(skip[:k]) >= skip[k]
    for k in range(1, q):
        assert sum(skip[: k - 1]) < skip[k]


def test_observation_2():
    # At most two k > 1 with skip[k-2] + skip[k-1] == skip[k]
    for p in range(2, 3000):
        skip = compute_skips(p)
        q = ceil_log2(p)
        cnt = sum(1 for k in range(2, q + 1) if skip[k - 2] + skip[k - 1] == skip[k])
        assert cnt <= 2, (p, skip)


# ------------------------------------------------------------ baseblock


def test_baseblock_root_is_q():
    for p in [1, 2, 5, 16, 17, 1000]:
        q = ceil_log2(p)
        assert baseblock(0, compute_skips(p), q) == q


def test_baseblock_power_of_two_is_lowest_set_bit():
    # For p = 2^q the baseblock of r is the index of the lowest set bit.
    p = 64
    q = 6
    skip = compute_skips(p)
    for r in range(1, p):
        assert baseblock(r, skip, q) == (r & -r).bit_length() - 1


def test_baseblock_decomposition_sums_to_r():
    # The canonical skip sequence reconstructed from repeated baseblocks
    # sums to r with strictly increasing skip indices (Lemma 1).
    for p in [17, 33, 100, 1021]:
        q = ceil_log2(p)
        skip = compute_skips(p)
        for r in range(p):
            rest, total, last = r, 0, -1
            while rest > 0:
                b = baseblock(rest, skip, q)
                assert b > last  # strictly increasing from the front
                total += skip[b]
                last = -1  # order within decomposition checked via greedy below
                rest2 = rest - skip[b]
                # greedy largest-first means the *smallest* index is removed
                # first here; just check termination and sum
                rest = rest2
            assert total == r


# ---------------------------------------------------- golden: paper tables


def test_table2_p17_golden():
    p = 17
    recv, send = schedule_tables(p)
    q = ceil_log2(p)
    skip = compute_skips(p)
    exp_b = [5, 0, 1, 2, 0, 3, 0, 1, 2, 4, 0, 1, 2, 0, 3, 0, 1]
    assert [baseblock(r, skip, q) for r in range(p)] == exp_b
    exp_recv = [
        [-4, 0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5],
        [-5, -4, 1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2],
        [-2, -2, -2, 2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3],
        [-1, -3, -3, -2, -2, 3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1],
        [-3, -1, -1, -1, -1, -1, -1, -1, -1, 4, 0, 1, 2, 0, 3, 0, 1],
    ]
    exp_send = [
        [0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5, -4],
        [1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2, -5, -4],
        [2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3, -2, -2, -2],
        [3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1, -1, -3, -3, -2, -2],
        [4, 0, 1, 2, 0, 3, 0, 1, -3, -1, -1, -1, -1, -1, -1, -1, -1],
    ]
    for k in range(q):
        assert [recv[r][k] for r in range(p)] == exp_recv[k], f"recv k={k}"
        assert [send[r][k] for r in range(p)] == exp_send[k], f"send k={k}"


def test_table1_p16_send_pattern():
    # Table 1 gives the *absolute* blocks sent per round in the first phase
    # for p=16 (power of two).  Our schedules are phase-relative; converting:
    # a processor's first-phase send in round k is its baseblock b if
    # sendblock[k] in {b-q, b} else sendblock[k] (mod-q normalized).  Rather
    # than re-deriving the table's absolute numbering we check the defining
    # property: for p = 2^q the send block pattern is "next set bit of r|p
    # at/after bit k" (§2.4).
    p, q = 16, 4
    recv, send = schedule_tables(p)
    skip = compute_skips(p)
    for r in range(1, p):
        for k in range(q):
            rp = r | p
            # next set bit at position >= k (the paper: after bit k-1)
            nb = next(i for i in range(k, q + 1) if (rp >> i) & 1)
            expect = nb if nb < q else q  # q means "own baseblock phase"
            got = send[r][k]
            b = baseblock(r, skip, q)
            # translate: got == b means sending own baseblock (current phase);
            # got == j - q (negative) means sending block j of previous phase.
            got_abs = got if got >= 0 else got + q
            assert got_abs == (expect if nb < q else b) or (
                nb == q and got == b - q
            ), (r, k, got, expect)


# ------------------------------------------------- correctness conditions


@pytest.mark.parametrize("p", list(range(1, 300)))
def test_conditions_small_p(p):
    verify_p(p)


@pytest.mark.parametrize(
    "p",
    [512, 1000, 1024, 1025, 2047, 2048, 2049, 4097, 5381, 8191, 10000, 65536, 65537],
)
def test_conditions_large_p(p):
    verify_p(p)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=2, max_value=1 << 16))
def test_conditions_hypothesis(p):
    verify_p(p)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=2, max_value=1 << 20))
def test_single_rank_schedule_properties(p):
    """Condition 3 per-rank on random large p without building all ranks."""
    import random

    q = ceil_log2(p)
    skip = compute_skips(p)
    rng = random.Random(p)
    for r in {0, 1, p - 1, rng.randrange(p), rng.randrange(p)}:
        rb = recv_schedule(p, r, skip)
        b = baseblock(r, skip, q)
        expect = set(range(-q, 0))
        if b < q:
            expect.discard(b - q)
            expect.add(b)
        assert set(rb) == expect
        sb = send_schedule(p, r, skip)
        if r == 0:
            assert sb == list(range(q))
        else:
            assert sb[0] == b - q


# ------------------------------------------------------ complexity bounds


def test_proposition1_recursion_bound():
    for p in list(range(2, 200)) + [1021, 4097, 65537]:
        q = ceil_log2(p)
        skip = compute_skips(p)
        for r in range(0, p, max(1, p // 128)):
            stats = [0]
            recv_schedule(p, r, skip, stats=stats)
            assert stats[0] <= 2 * q + 1, (p, r, stats[0], q)


def test_proposition3_violation_bound():
    worst = 0
    for p in list(range(2, 200)) + [1021, 4097]:
        skip = compute_skips(p)
        for r in range(p):
            v = [0]
            send_schedule(p, r, skip, violations=v)
            worst = max(worst, v[0])
    assert worst <= 4, worst


# ------------------------------------------------------- legacy baselines


@pytest.mark.parametrize("p", [1, 2, 3, 16, 17, 33, 100, 255, 257])
def test_legacy_matches_new(p):
    skip = compute_skips(p)
    for r in range(p):
        assert recv_schedule_legacy(p, r, skip) == recv_schedule(p, r, skip)
        assert send_schedule_legacy(p, r, skip) == send_schedule(p, r, skip)
        assert send_schedule_from_recv(p, r, skip) == send_schedule(p, r, skip)


# ------------------------------------------------------------------ misc


def test_virtual_rounds():
    for p in [2, 5, 16, 17]:
        q = ceil_log2(p)
        for n in range(1, 4 * q + 2):
            x = virtual_rounds(p, n)
            assert 0 <= x < q
            assert (n - 1 + q + x) % q == 0
