"""int8 compression + quantized circulant allreduce: arithmetic and
data-plane certification (single process).

The centerpiece certifies the quantized-allreduce host data plane
bit-for-bit against an independent pure-NumPy replay of the schedule:
same slot tables, but every quantize / dequantize / accumulate done in
plain ``np.float32`` ops -- if the jnp oracle or the Pallas kernel
reorders, fuses (FMA) or widens any arithmetic, the comparison breaks
in the last bit.  Multi-device behaviour (shard_map, error-feedback
completeness under psum, trainer parity) lives in test_collectives.py
via tests/mp_worker.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import host_plan
from repro.optim.compression import (
    BLOCK,
    BucketSpec,
    block_nonfinite,
    bucketize,
    dequantize_int8,
    init_error_state,
    make_bucket_spec,
    quantize_int8,
    unbucketize,
)

# --------------------------------------------------------------- NumPy
# reference arithmetic.  Quantize (amax, scale, round, clip) is plain
# float32, round-half-even -- both np.round and jnp.round.  The data
# plane's accumulate (``cur + q*s``) and error capture (``x - q*s``)
# compile to fused multiply-adds (one rounding, no intermediate f32
# product); NumPy reproduces an f32 FMA exactly through float64: the
# product q*s is EXACT in f64 (33-bit significand at most), so
# f32(f64(cur) + f64(q)*f64(s)) applies the same single rounding.


def np_fma(a, q, s, sign=1.0):
    """f32 fused multiply-add a + sign*q*s, emulated exactly in f64."""
    out = (np.asarray(a, np.float64) +
           np.float64(sign) * np.asarray(q, np.float64) *
           np.asarray(s, np.float64)).astype(np.float32)
    return out


def np_quant_blocks(x2d):
    x2d = np.asarray(x2d, np.float32)
    finite = np.isfinite(x2d)
    xf = np.where(finite, x2d, np.float32(0.0)).astype(np.float32)
    amax = np.max(np.abs(xf), axis=1, keepdims=True).astype(np.float32)
    inv127 = np.float32(1.0) / np.float32(127.0)
    scale = np.maximum(amax * inv127, np.float32(1e-12))
    q = np.clip(np.round(xf / scale), -127, 127).astype(np.int8)
    allf = finite.all(axis=1, keepdims=True)
    return q, np.where(allf, scale, np.float32(np.nan)).astype(np.float32)


def np_dequant_blocks(q, scale):
    return (q.astype(np.float32) * scale).astype(np.float32)


def np_quant_error(x2d, q, scale):
    err = np_fma(x2d, q, np.broadcast_to(scale, x2d.shape), sign=-1.0)
    return np.where(np.isfinite(err), err, np.float32(0.0)).astype(np.float32)


def np_quantized_allreduce(plan, vals):
    """Pure-NumPy replay of HostDataPlan._run_quantized using the
    plan's own slot tables: reduce-phase qacc rounds (dequantize ->
    accumulate -> requantize forward slot -> capture error -> drain),
    root requantization, then the int8+scales broadcast phase."""
    p, n, qb = plan.p, plan.n, plan.qblock
    fwd_slots, acc_slots, recv_slots, send_slots = plan.slots
    red_skips, bc_skips = plan.skips
    vals = np.asarray(vals, np.float32)               # [p, n, bs]
    bs = vals.shape[-1]
    nb = bs // qb
    buf = np.concatenate([vals, np.zeros((p, 2, bs), np.float32)], axis=1)
    err = np.zeros_like(buf)

    def qacc(buf, err, qmsg, smsg, acc_idx, fwd_idx):
        qout = np.zeros((p, bs), np.int8)
        sout = np.zeros((p, nb), np.float32)
        for r in range(p):
            buf[r, acc_idx[r]] = np_fma(
                buf[r, acc_idx[r]].reshape(nb, qb),
                qmsg[r].reshape(nb, qb),
                np.broadcast_to(smsg[r].reshape(nb, 1), (nb, qb)),
            ).reshape(bs)
            captured = buf[r, fwd_idx[r]].reshape(nb, qb)
            q, s = np_quant_blocks(captured)
            err[r, fwd_idx[r]] += np_quant_error(captured, q, s).reshape(bs)
            buf[r, fwd_idx[r]] = 0.0
            qout[r], sout[r] = q.reshape(bs), s.reshape(nb)
        return qout, sout

    garbage = np.full((p,), n, np.int64)
    qm, sm = qacc(buf, err, np.zeros((p, bs), np.int8),
                  np.zeros((p, nb), np.float32), garbage, fwd_slots[0])
    R = len(red_skips)
    for t in range(R):
        gq = np.roll(qm, -red_skips[t], axis=0)
        gs = np.roll(sm, -red_skips[t], axis=0)
        nxt = fwd_slots[t + 1] if t + 1 < R else garbage
        qm, sm = qacc(buf, err, gq, gs, acc_slots[t], nxt)

    droot = buf[plan.root, :n].reshape(n * nb, qb)
    q, sc = np_quant_blocks(droot)
    err[plan.root, :n] += np_quant_error(droot, q, sc).reshape(n, bs)
    qbuf = np.zeros((p, n + 1, bs), np.int8)
    qbuf[plan.root, :n] = q.reshape(n, bs)
    sbuf = np.zeros((p, n + 1, nb), np.float32)
    sbuf[plan.root, :n] = sc.reshape(n, nb)

    def pack(b, idx):
        return np.stack([b[r, idx[r]] for r in range(p)])

    msgq, msgs = pack(qbuf, send_slots[0]), pack(sbuf, send_slots[0])
    Rb = len(bc_skips)
    for t in range(Rb):
        gq = np.roll(msgq, bc_skips[t], axis=0)
        gs = np.roll(msgs, bc_skips[t], axis=0)
        for r in range(p):
            qbuf[r, recv_slots[t][r]] = gq[r]
            sbuf[r, recv_slots[t][r]] = gs[r]
        if t + 1 < Rb:
            msgq = pack(qbuf, send_slots[t + 1])
            msgs = pack(sbuf, send_slots[t + 1])
    out = np_dequant_blocks(qbuf[:, :n].reshape(p * n * nb, qb),
                            sbuf[:, :n].reshape(p * n * nb, 1))
    return out.reshape(p, n, bs), err[:, :n]


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("p,n", [(2, 1), (3, 2), (5, 4), (8, 2)])
def test_quantized_allreduce_bitexact_vs_numpy(backend, p, n):
    """Quantized circulant allreduce == independent NumPy replay,
    bit-for-bit, on both data-plane backends."""
    qb = 8
    plan = host_plan("quantized_allreduce", p, n, backend=backend,
                     qblock=qb)
    rng = np.random.default_rng(100 * p + n)
    # high dynamic range across quantization blocks
    vals = (rng.normal(size=(p, n, 3 * qb)) *
            10.0 ** rng.integers(-4, 5, size=(p, n, 1))).astype(np.float32)
    out, err = plan.run(vals)
    ref_out, ref_err = np_quantized_allreduce(plan, vals)
    np.testing.assert_array_equal(out, ref_out)
    np.testing.assert_array_equal(err, ref_err)
    # every rank's row identical; completeness vs the exact f32 sum
    for r in range(1, p):
        np.testing.assert_array_equal(out[r], out[0])
    exact = vals.astype(np.float64).sum(0)
    recon = out[0].astype(np.float64) + err.astype(np.float64).sum(0)
    resid = np.abs(recon - exact)
    tol = 1e-4 * np.maximum(np.abs(exact), np.abs(vals).max(0) * p) + 1e-7
    assert (resid <= tol).all(), resid.max()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_quantized_allreduce_nonfinite_bitexact(backend):
    """NaN/inf lanes: flagged blocks come back all-NaN on every rank,
    error state stays finite, and jnp/pallas/NumPy still agree
    bit-for-bit (NaN positions included)."""
    p, n, qb = 3, 2, 8
    plan = host_plan("quantized_allreduce", p, n, backend=backend,
                     qblock=qb)
    rng = np.random.default_rng(3)
    vals = rng.normal(size=(p, n, 3 * qb)).astype(np.float32)
    vals[1, 0, qb + 2] = np.nan
    vals[0, 1, 2 * qb] = np.inf
    out, err = plan.run(vals)
    ref_out, ref_err = np_quantized_allreduce(plan, vals)
    np.testing.assert_array_equal(out, ref_out)
    np.testing.assert_array_equal(err, ref_err)
    assert np.isfinite(err).all()
    for r in range(p):
        assert np.isnan(out[r, 0, qb:2 * qb]).all()
        assert np.isnan(out[r, 1, 2 * qb:3 * qb]).all()
        assert np.isfinite(out[r, 0, :qb]).all()
        assert np.isfinite(out[r, 0, 2 * qb:]).all()


def test_host_plan_identity_and_validation():
    plan = host_plan("quantized_allreduce", 4, 2, qblock=8)
    assert host_plan("quantized_allreduce", 4, 2, qblock=8) is plan
    assert host_plan("quantized_allreduce", 4, 2, qblock=16) is not plan
    with pytest.raises(ValueError, match="qblock"):
        host_plan("broadcast", 4, 2, qblock=8)
    with pytest.raises(ValueError, match="sums"):
        host_plan("quantized_allreduce", 4, 2, op="max")


# ------------------------------------------------------------ quantize


def test_quantize_nonfinite_blocks():
    """A NaN or inf poisons exactly its own block -- flagged via a NaN
    scale, dequantizing to all-NaN -- and neighbouring blocks are
    untouched; finite lanes of the bad block still quantize sanely."""
    rng = np.random.default_rng(0)
    v = rng.normal(size=(4 * BLOCK,)).astype(np.float32)
    v[BLOCK + 3] = np.nan
    v[2 * BLOCK + 7] = -np.inf
    q, s = jax.jit(quantize_int8)(jnp.asarray(v))
    flags = np.asarray(block_nonfinite(s)).reshape(-1)
    assert flags.tolist() == [False, True, True, False]
    dq = np.asarray(jax.jit(dequantize_int8)(q, s))
    assert np.isnan(dq[BLOCK:3 * BLOCK]).all()
    assert np.isfinite(dq[:BLOCK]).all() and np.isfinite(dq[3 * BLOCK:]).all()
    # clean blocks round-trip within one quantization step
    assert np.abs(dq[:BLOCK] - v[:BLOCK]).max() <= np.abs(v[:BLOCK]).max() / 127
    # the bad block's finite lanes were quantized against the finite
    # amax (wire content preserved modulo the flag)
    qb = np.asarray(q).reshape(4, BLOCK)[1]
    fin = np.isfinite(v[BLOCK:2 * BLOCK])
    assert np.abs(qb[fin]).max() > 0


def test_quantize_zero_and_tiny_blocks():
    """All-zero and denormal-scale blocks: the 1e-12 scale floor must
    yield exact zeros (not garbage) and zero error."""
    v = np.zeros((2 * BLOCK,), np.float32)
    v[BLOCK:] = 1e-30
    q, s = quantize_int8(jnp.asarray(v))
    assert not np.asarray(block_nonfinite(s)).any()
    dq = np.asarray(dequantize_int8(q, s))
    np.testing.assert_array_equal(dq[:BLOCK], 0.0)
    # sub-floor magnitudes quantize to exact zero (their full value is
    # the quantization error, recovered by the feedback loop)
    np.testing.assert_array_equal(dq[BLOCK:], 0.0)


def test_error_state_is_f32_for_low_precision_params():
    params = {"a": jnp.zeros((3, 4), jnp.bfloat16),
              "b": jnp.zeros((7,), jnp.float16)}
    err = init_error_state(params)
    assert all(e.dtype == jnp.float32 for e in jax.tree.leaves(err))


# ------------------------------------------------------------- buckets


def test_bucket_spec_and_roundtrip_ragged():
    shapes = {"w1": (17, 9), "b1": (9,), "w2": (9, 23), "b2": (23,),
              "scalar": ()}
    params = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
    spec = make_bucket_spec(params, bucket_bytes=4 * 150)
    assert isinstance(spec, BucketSpec)
    assert spec.num_buckets > 1
    assert sum(spec.bucket_sizes) == sum(
        int(np.prod(s)) if s else 1 for s in shapes.values())
    assert hash(spec) == hash(make_bucket_spec(params, bucket_bytes=4 * 150))

    rng = np.random.default_rng(5)
    tree = {k: jnp.asarray(rng.normal(size=s).astype(np.float32))
            for k, s in shapes.items()}
    flats = bucketize(tree, spec)
    assert [f.shape[0] for f in flats] == list(spec.bucket_sizes)
    back, deltas = unbucketize(flats, spec, tree)
    for k in shapes:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    assert all(not np.asarray(d).any() for d in deltas)


def test_bucket_oversized_leaf_gets_own_bucket():
    # dict leaves flatten in key order: huge, small, tail
    params = {"small": jnp.zeros((10,)), "huge": jnp.zeros((1000,)),
              "tail": jnp.zeros((5,))}
    spec = make_bucket_spec(params, bucket_bytes=4 * 64)
    assert spec.num_buckets == 2
    assert spec.bucket_sizes == (1000, 15)
    assert spec.assignment == (0, 1, 1)


def test_unbucketize_downcast_delta():
    """bf16 leaves: the downcast loss lands in the delta vectors (the
    error-feedback hook), and cast + delta reconstructs f32 exactly."""
    tree = {"x": jnp.zeros((300,), jnp.bfloat16)}
    spec = make_bucket_spec(tree)
    rng = np.random.default_rng(9)
    flat = jnp.asarray(rng.normal(size=300).astype(np.float32))
    out, deltas = unbucketize([flat], spec, tree)
    assert out["x"].dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out["x"], np.float32) + np.asarray(deltas[0]),
        np.asarray(flat), rtol=0, atol=0)
    assert np.asarray(deltas[0]).any()


def test_bucketize_validates_leaf_count():
    spec = make_bucket_spec({"a": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="leaves"):
        bucketize({"a": jnp.zeros((4,)), "b": jnp.zeros((4,))}, spec)
