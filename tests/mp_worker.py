"""Multi-device collective worker: run under XLA host-device flags.

Invoked as a subprocess by test_collectives.py (and by the collective
benchmarks) so that the main process keeps its single-device view:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python tests/mp_worker.py <what> <p>
"""

import os
import sys

if __name__ == "__main__":
    what = sys.argv[1] if len(sys.argv) > 1 else "all"
    p = int(sys.argv[2]) if len(sys.argv) > 2 else 8
    backend = sys.argv[3] if len(sys.argv) > 3 else "jnp"
    # "hier" mode: argv[4] is the node count of the nodes x cores mesh
    # (cores = p // nodes).
    nodes = int(sys.argv[4]) if len(sys.argv) > 4 else 2
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={p}"
    )

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.collectives import (
    circulant_allbroadcast,
    circulant_allgather,
    circulant_allgatherv,
    circulant_allreduce,
    circulant_broadcast,
    circulant_reduce,
    ring_allgather,
)


def make_mesh(p):
    return Mesh(np.array(jax.devices()[:p]), ("data",))


def sharded(mesh, arr):
    return jax.device_put(arr, NamedSharding(mesh, P("data")))


def check_broadcast(p, n_blocks, root, elems=97, dtype=jnp.float32,
                    backend="jnp"):
    mesh = make_mesh(p)
    rng = np.random.default_rng(0)
    data = rng.normal(size=(p, elems)).astype(dtype)
    x = sharded(mesh, jnp.asarray(data))
    out = jax.jit(
        lambda a: circulant_broadcast(mesh, "data", a, n_blocks=n_blocks,
                                      root=root, backend=backend)
    )(x)
    out = np.asarray(out)
    for r in range(p):
        np.testing.assert_allclose(out[r], data[root], rtol=0, atol=0)
    print(f"broadcast p={p} n={n_blocks} root={root} backend={backend} ok")


def check_allgather(p, n_blocks, elems=64, dtype=jnp.float32, backend="jnp"):
    mesh = make_mesh(p)
    rng = np.random.default_rng(1)
    data = rng.normal(size=(p * elems,)).astype(dtype)
    x = sharded(mesh, jnp.asarray(data))
    out = jax.jit(
        lambda a: circulant_allgather(mesh, "data", a, n_blocks=n_blocks,
                                      backend=backend)
    )(x)
    np.testing.assert_allclose(np.asarray(out), data, rtol=0, atol=0)
    print(f"allgather p={p} n={n_blocks} backend={backend} ok")


def check_allgatherv(p, n_blocks, sizes, dtype=jnp.int32, backend="jnp"):
    mesh = make_mesh(p)
    cap = max(max(sizes), 1)
    rng = np.random.default_rng(2)
    rows = np.zeros((p, cap), dtype=np.int32)
    for j in range(p):
        rows[j, : sizes[j]] = rng.integers(0, 1000, size=sizes[j])
    x = sharded(mesh, jnp.asarray(rows))
    out = jax.jit(
        lambda a: circulant_allgatherv(mesh, "data", a, sizes,
                                       n_blocks=n_blocks, backend=backend)
    )(x)
    out = np.asarray(out)
    for j in range(p):
        np.testing.assert_array_equal(out[j, : sizes[j]], rows[j, : sizes[j]])
    print(f"allgatherv p={p} n={n_blocks} sizes={sizes} backend={backend} ok")


def check_compressed_allreduce(p, elems=2048, backend="jnp"):
    """Both lossy transports (legacy ring, quantized circulant): mean
    contract, COMPLETE error feedback vs the exact f32 psum on
    adversarial high-dynamic-range gradients, ragged leaf sizes,
    bf16 leaves, and nonfinite propagation."""
    from jax.sharding import PartitionSpec as P
    from repro.core.jaxcompat import shard_map
    from repro.optim.compression import (
        BLOCK,
        compressed_allreduce_tree,
        init_error_state,
    )

    mesh = make_mesh(p)
    rng = np.random.default_rng(7)
    # adversarial dynamic range: per-block magnitudes spanning 12 decades
    # (a uniform-scale gradient hides the per-hop error bug -- partial
    # sums then quantize with ~the same scale as the inputs).
    nblk = max(1, elems // BLOCK)
    mags = 10.0 ** rng.integers(-6, 6, size=(p, nblk, 1))
    data = (rng.normal(size=(p, nblk, BLOCK)) * mags).astype(
        np.float32).reshape(p, -1)
    elems = data.shape[1]
    # ragged second leaf: not divisible by p*BLOCK (padded-tail error
    # accounting), bf16 third leaf (f32 error state + downcast delta).
    rag = rng.normal(size=(p, 3 * BLOCK + 17)).astype(np.float32) * 100.0
    bfl = rng.normal(size=(p, 37)).astype(np.float32)

    for transport in ("ring", "circulant"):
        def body(xs, ys, zs):
            g = {"w": xs[0], "r": ys[0], "t": zs[0].astype(jnp.bfloat16)}
            e = init_error_state(g)
            red, new_e = compressed_allreduce_tree(
                g, e, "data", p, transport=transport, backend=backend)
            tot = jax.tree.map(lambda v: jax.lax.psum(v, "data"), new_e)
            red = jax.tree.map(lambda v: v.astype(jnp.float32), red)
            return (jax.tree.map(lambda v: v[None], red),
                    jax.tree.map(lambda v: v[None], tot))

        red, tot = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("data"),) * 3,
            out_specs=({k: P("data") for k in "wrt"},) * 2,
            check_vma=False,
        ))(sharded(mesh, jnp.asarray(data)),
           sharded(mesh, jnp.asarray(rag)),
           sharded(mesh, jnp.asarray(bfl)))
        srcs = {"w": data, "r": rag,
                "t": np.asarray(jnp.asarray(bfl).astype(jnp.bfloat16),
                                np.float32)}
        for k, src in srcs.items():
            exact_sum = src.astype(np.float64).sum(0)
            got = np.asarray(red[k], np.float64)
            te = np.asarray(tot[k], np.float64)
            # mean contract (loose sanity: one-shot lossy error is set by
            # the quantization-block amax, ~amax*p/127 per element; the
            # tight per-element claim is the completeness check below)
            lim = np.float64(5.0) * p * np.abs(src).max() / 127.0 + 1e-6
            assert (np.abs(got - exact_sum[None] / p) < lim).all()
            # completeness: exact_sum == p*mean + psum(err), to f32
            # accumulation tolerance -- this is what the old ring failed
            # by a factor of p plus every dropped per-hop error.
            for r in range(p):
                resid = np.abs(got[r] * p + te[r] - exact_sum)
                tol = 1e-4 * np.maximum(np.abs(exact_sum),
                                        np.abs(src).max(0) * p) + 1e-6
                assert (resid <= tol).all(), (
                    f"{transport}/{k} r={r}: error feedback incomplete, "
                    f"max resid {resid.max():.3e}")
        print(f"compressed_allreduce p={p} transport={transport} "
              f"backend={backend} ok")

    # nonfinite: a NaN lane poisons exactly its own quantization block
    # in the result (deterministic all-NaN), never the error state.
    bad = data.copy()
    bad[0, BLOCK + 3] = np.nan

    def nf_body(xs):
        g = {"w": xs[0]}
        e = init_error_state(g)
        red, new_e = compressed_allreduce_tree(g, e, "data", p,
                                               backend=backend)
        return red["w"][None], new_e["w"][None]

    red, err = jax.jit(shard_map(
        nf_body, mesh=mesh, in_specs=P("data"),
        out_specs=(P("data"), P("data")), check_vma=False,
    ))(sharded(mesh, jnp.asarray(bad)))
    red, err = np.asarray(red), np.asarray(err)
    for r in range(p):
        assert np.isnan(red[r, BLOCK:2 * BLOCK]).all(), \
            "NaN block not propagated"
        assert np.isfinite(red[r, 2 * BLOCK:]).all()
        assert np.isfinite(red[r, :BLOCK]).all()
    assert np.isfinite(err).all(), "error state poisoned by NaN input"
    print(f"compressed_allreduce p={p} nonfinite backend={backend} ok")


def check_gradsync(p, backend="jnp", steps=20):
    """End-to-end trainer parity: grad_sync='compressed' tracks
    grad_sync='auto' loss within bounded divergence over ``steps``
    optimizer steps (same data, same init)."""
    from repro.configs import get_config
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import (
        TrainConfig,
        init_train_state,
        make_train_step,
    )

    mesh = make_mesh(p)
    cfg = get_config("qwen2-0.5b", smoke=True)
    B, S = 2 * p, 32
    rng = np.random.default_rng(41)
    toks = rng.integers(0, cfg.vocab, size=(steps, B, S))

    def run(grad_sync):
        tcfg = TrainConfig(
            microbatches=2, remat="none",
            opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps),
            dp_axes=("data",), grad_sync=grad_sync,
            grad_sync_backend=backend,
        )
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0),
                                 mesh=mesh)
        step = jax.jit(make_train_step(cfg, tcfg, mesh=mesh))
        losses = []
        with mesh:
            for i in range(steps):
                tok = sharded(mesh, jnp.asarray(toks[i]))
                state, m = step(state, {"tokens": tok, "labels": tok})
                losses.append(float(m["loss"]))
        return np.array(losses)

    auto = run("auto")
    comp = run("compressed")
    # both must actually train...
    assert auto[-1] < auto[0] and comp[-1] < comp[0], (auto, comp)
    # ...and stay within bounded divergence: int8 + error feedback is a
    # tiny perturbation at these scales.
    div = np.abs(auto - comp)
    assert div.max() < 0.05 * max(1.0, auto[0]), \
        f"loss trajectories diverged: {div.max():.4f}\nauto={auto}\ncomp={comp}"
    print(f"gradsync parity p={p} backend={backend} ok "
          f"(max |auto-comp| {div.max():.4g} over {steps} steps)")


def check_overlap(p, backend="jnp"):
    """Overlapped (double-buffered) executor vs sequential on a live
    mesh: distinct cached plans, bit-equal outputs for every kind that
    gains the mode (mixed-dtype pytrees, nonzero roots, max reduces)."""
    from repro.core.comm import get_comm

    mesh = make_mesh(p)
    comm = get_comm(mesh, "data", backend=backend)
    rng = np.random.default_rng(43)
    xs = {"w": sharded(mesh, jnp.asarray(
        rng.normal(size=(p, 37)).astype(np.float32))),
        "b": sharded(mesh, jnp.asarray(
            rng.integers(-9, 9, size=(p, 11)).astype(np.int32)))}
    for kind in ("broadcast", "allgather", "reduce", "allreduce"):
        rooted = kind in ("broadcast", "reduce")
        kw = dict(n_blocks=3, root=p - 1 if rooted else 0)
        seq = comm.plan(kind, xs, **kw)
        ovl = comm.plan(kind, xs, overlap=True, **kw)
        assert ovl is not seq and ovl.overlap and not seq.overlap, \
            f"{kind}: overlap plan not distinct from sequential"
        a, b = seq(xs), ovl(xs)
        for k in ("w", "b"):
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
        print(f"overlap {kind} p={p} backend={backend} ok")
    # max-op reduce: the staged drain path must match for non-sum ops.
    fs = {"a": xs["w"]}
    a = comm.reduce(fs, n_blocks=2, root=0, op="max")
    b = comm.reduce(fs, n_blocks=2, root=0, op="max", overlap=True)
    np.testing.assert_array_equal(np.asarray(a["a"]), np.asarray(b["a"]))
    print(f"overlap reduce(max) p={p} backend={backend} ok")
    # reduce_scatter needs p-divisible shards.
    m = {"m": sharded(mesh, jnp.asarray(
        rng.normal(size=(p, p * 8)).astype(np.float32)))}
    a = comm.reduce_scatter(m, n_blocks=2)
    b = comm.reduce_scatter(m, n_blocks=2, overlap=True)
    np.testing.assert_array_equal(np.asarray(a["m"]), np.asarray(b["m"]))
    print(f"overlap reduce_scatter p={p} backend={backend} ok")
    # unsupported kinds must be rejected, not silently sequential.
    try:
        comm.plan("quantized_allreduce", {"g": sharded(mesh, jnp.asarray(
            rng.normal(size=(p, 512)).astype(np.float32)))},
            qblock=256, overlap=True)
    except ValueError:
        pass
    else:
        raise AssertionError("quantized_allreduce accepted overlap=True")


def check_gradsync_stream(p, backend="jnp", steps=12):
    """Streamed (in-backward, bucket-at-a-time) vs post-backward
    compressed grad sync: loss trajectories stay within bounded
    divergence over ``steps`` optimizer steps (same data, same init),
    with and without gradient accumulation."""
    from repro.configs import get_config
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import (
        TrainConfig,
        init_train_state,
        make_train_step,
    )

    mesh = make_mesh(p)
    cfg = get_config("qwen2-0.5b", smoke=True)
    B, S = 2 * p, 32
    rng = np.random.default_rng(47)
    toks = rng.integers(0, cfg.vocab, size=(steps, B, S))

    def run(stream, microbatches):
        tcfg = TrainConfig(
            microbatches=microbatches, remat="none",
            opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps),
            dp_axes=("data",), grad_sync="compressed",
            grad_sync_backend=backend, stream_grad_sync=stream,
        )
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0),
                                 mesh=mesh)
        step = jax.jit(make_train_step(cfg, tcfg, mesh=mesh))
        losses = []
        with mesh:
            for i in range(steps):
                tok = sharded(mesh, jnp.asarray(toks[i]))
                state, m = step(state, {"tokens": tok, "labels": tok})
                losses.append(float(m["loss"]))
        return np.array(losses)

    for mb in (1, 2):
        base = run(False, mb)
        strm = run(True, mb)
        assert base[-1] < base[0] and strm[-1] < strm[0], (base, strm)
        div = np.abs(base - strm)
        assert div.max() < 0.05 * max(1.0, base[0]), (
            f"streamed sync diverged (microbatches={mb}): {div.max():.4f}"
            f"\nbase={base}\nstrm={strm}")
        print(f"gradsync stream parity p={p} microbatches={mb} "
              f"backend={backend} ok (max div {div.max():.4g})")


def check_reduce_scatter(p):
    from repro.core.collectives import circulant_reduce_scatter

    mesh = make_mesh(p)
    rng = np.random.default_rng(13)
    for n in (1, 2, 3, 6):
        L = p * 24
        data = rng.normal(size=(p, L)).astype(np.float32)
        x = sharded(mesh, jnp.asarray(data))
        out = jax.jit(
            lambda a: circulant_reduce_scatter(mesh, "data", a, n_blocks=n)
        )(x)
        out = np.asarray(out)
        expect = data.sum(axis=0).reshape(p, -1)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-4)
        print(f"reduce_scatter p={p} n={n} ok")


def check_restore_broadcast(p):
    """Restore fan-out: root rank's checkpoint pytree reaches every rank."""
    from jax.sharding import PartitionSpec as P
    from repro.train.restore_broadcast import broadcast_state

    mesh = make_mesh(p)
    rng = np.random.default_rng(11)
    w = rng.normal(size=(p, 33, 7)).astype(np.float32)   # only row 0 is "real"
    b = rng.normal(size=(p, 13)).astype(np.float32)
    state = {
        "w": sharded(mesh, jnp.asarray(w)),
        "b": sharded(mesh, jnp.asarray(b)),
    }
    out = jax.jit(lambda s: broadcast_state(mesh, "data", s, n_blocks=3))(state)
    for r in range(p):
        np.testing.assert_allclose(np.asarray(out["w"])[r], w[0], atol=0)
        np.testing.assert_allclose(np.asarray(out["b"])[r], b[0], atol=0)
    print(f"restore_broadcast p={p} ok")


def check_reduce(p, backend="jnp"):
    """Reversed-schedule reduction: root slice = op-reduction, rest zero."""
    mesh = make_mesh(p)
    rng = np.random.default_rng(17)
    for n in (1, 2, 3, 5):
        for root in sorted({0, p - 1}):
            data = rng.integers(-1000, 1000, size=(p, 41)).astype(np.int32)
            x = sharded(mesh, jnp.asarray(data))
            out = np.asarray(jax.jit(
                lambda a: circulant_reduce(mesh, "data", a, n_blocks=n,
                                           root=root, backend=backend)
            )(x))
            np.testing.assert_array_equal(out[root], data.sum(axis=0))
            for r in range(p):
                if r != root:
                    assert not out[r].any(), f"non-root rank {r} not zeroed"
            fdata = rng.normal(size=(p, 41)).astype(np.float32)
            xf = sharded(mesh, jnp.asarray(fdata))
            outf = np.asarray(jax.jit(
                lambda a: circulant_reduce(
                    mesh, "data", a, n_blocks=n, root=root, op="max",
                    backend=backend)
            )(xf))
            np.testing.assert_array_equal(outf[root], fdata.max(axis=0))
            print(f"reduce p={p} n={n} root={root} backend={backend} ok")


def check_allreduce(p, backend="jnp"):
    """Composed reduce+broadcast: every rank holds the full reduction."""
    mesh = make_mesh(p)
    rng = np.random.default_rng(19)
    for n in (1, 2, 4):
        data = rng.integers(-1000, 1000, size=(p, 53)).astype(np.int32)
        x = sharded(mesh, jnp.asarray(data))
        out = np.asarray(jax.jit(
            lambda a: circulant_allreduce(mesh, "data", a, n_blocks=n,
                                          backend=backend)
        )(x))
        expect = data.sum(axis=0)
        for r in range(p):
            np.testing.assert_array_equal(out[r], expect)
        fdata = rng.normal(size=(p, 53)).astype(np.float32)
        xf = sharded(mesh, jnp.asarray(fdata))
        outf = np.asarray(jax.jit(
            lambda a: circulant_allreduce(mesh, "data", a, n_blocks=n,
                                          op="max", backend=backend)
        )(xf))
        expectf = fdata.max(axis=0)
        for r in range(p):
            np.testing.assert_array_equal(outf[r], expectf)
        print(f"allreduce p={p} n={n} backend={backend} ok")


def check_allbroadcast(p, elems=48):
    mesh = make_mesh(p)
    rng = np.random.default_rng(23)
    for n in (1, 3):
        data = rng.normal(size=(p * elems,)).astype(np.float32)
        x = sharded(mesh, jnp.asarray(data))
        out = np.asarray(jax.jit(
            lambda a: circulant_allbroadcast(mesh, "data", a, n_blocks=n)
        )(x))
        np.testing.assert_allclose(out, data, rtol=0, atol=0)
        print(f"allbroadcast p={p} n={n} ok")


def check_comm(p, backend="jnp"):
    """Plan/execute communicator with pytree payloads: dict/tuple trees,
    mixed dtypes, ragged leaves (sizes not divisible by n_blocks), both
    data-plane backends -- certified bit-exact against per-leaf NumPy
    references, with plan-cache identity asserted along the way."""
    from repro.core.comm import get_comm, payload_spec

    mesh = make_mesh(p)
    comm = get_comm(mesh, "data", backend=backend)
    rng = np.random.default_rng(29)

    # ---- broadcast: dict-of-(arrays + tuple) payload, mixed dtypes,
    # ragged leaf sizes (111, 11, 5 elems with n=4 blocks), nonzero root.
    root = p - 1
    state = {
        "w": rng.normal(size=(p, 37, 3)).astype(np.float32),
        "b": rng.integers(0, 100, size=(p, 11)).astype(np.int32),
        "t": (rng.normal(size=(p, 5)).astype(jnp.bfloat16),),
    }
    xs = {"w": sharded(mesh, jnp.asarray(state["w"])),
          "b": sharded(mesh, jnp.asarray(state["b"])),
          "t": (sharded(mesh, jnp.asarray(state["t"][0])),)}
    plan = comm.plan("broadcast", xs, n_blocks=4, root=root)
    assert plan is comm.plan("broadcast", payload_spec(xs), n_blocks=4,
                             root=root), "plan cache lost identity"
    out = plan(xs)
    for k in ("w", "b"):
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.broadcast_to(state[k][root], state[k].shape))
    np.testing.assert_array_equal(
        np.asarray(out["t"][0], np.float32),
        np.broadcast_to(np.asarray(state["t"][0], np.float32)[root],
                        state["t"][0].shape))
    out2 = plan(xs)  # second execution reuses the compiled rounds
    np.testing.assert_array_equal(np.asarray(out2["b"]), np.asarray(out["b"]))
    print(f"comm broadcast pytree p={p} root={root} backend={backend} ok")

    # ---- reduce: int sum is bit-exact; non-root slices zeroed.
    data = {"a": rng.integers(-50, 50, size=(p, 13)).astype(np.int32),
            "b": rng.integers(-50, 50, size=(p, 7, 2)).astype(np.int32)}
    ds = {k: sharded(mesh, jnp.asarray(v)) for k, v in data.items()}
    red = comm.reduce(ds, n_blocks=3, root=1)
    np.testing.assert_array_equal(np.asarray(red["a"])[1], data["a"].sum(0))
    np.testing.assert_array_equal(np.asarray(red["b"])[1], data["b"].sum(0))
    for r in range(p):
        if r != 1:
            assert not np.asarray(red["a"])[r].any()
    # float max is bit-exact too
    fdata = {"a": rng.normal(size=(p, 13)).astype(np.float32),
             "b": rng.normal(size=(p, 7, 2)).astype(np.float32)}
    fs = {k: sharded(mesh, jnp.asarray(v)) for k, v in fdata.items()}
    fred = comm.reduce(fs, n_blocks=3, root=0, op="max")
    np.testing.assert_array_equal(np.asarray(fred["a"])[0], fdata["a"].max(0))
    print(f"comm reduce pytree p={p} backend={backend} ok")

    # ---- allreduce: every rank ends with the per-leaf reduction.
    ar = comm.allreduce(ds, n_blocks=2)
    for r in range(p):
        np.testing.assert_array_equal(np.asarray(ar["a"])[r], data["a"].sum(0))
        np.testing.assert_array_equal(np.asarray(ar["b"])[r], data["b"].sum(0))
    print(f"comm allreduce pytree p={p} backend={backend} ok")

    # ---- allgather: replicated per-leaf, ragged shard sizes.
    g = {"x": rng.normal(size=(p * 6,)).astype(np.float32),
         "y": rng.integers(0, 9, size=(p, 4)).astype(np.int32)}
    gs = {k: sharded(mesh, jnp.asarray(v)) for k, v in g.items()}
    got = comm.allgather(gs, n_blocks=3)
    np.testing.assert_array_equal(np.asarray(got["x"]), g["x"])
    np.testing.assert_array_equal(np.asarray(got["y"]), g["y"])
    print(f"comm allgather pytree p={p} backend={backend} ok")

    # ---- reduce_scatter: summed shards, scattered rows.  The int case
    # uses magnitudes beyond float32's 24-bit mantissa, so it fails if
    # partials ever detour through float32 -- integer sums accumulate
    # natively and must be bit-exact.
    m = rng.normal(size=(p, p * 8)).astype(np.float32)
    rs = comm.reduce_scatter({"m": sharded(mesh, jnp.asarray(m))}, n_blocks=2)
    np.testing.assert_allclose(np.asarray(rs["m"]), m.sum(0).reshape(p, 8),
                               rtol=1e-5, atol=1e-4)
    mi = (rng.integers(-1000, 1000, size=(p, p * 8)) * 100003).astype(np.int32)
    rsi = comm.reduce_scatter({"m": sharded(mesh, jnp.asarray(mi))},
                              n_blocks=3)
    np.testing.assert_array_equal(np.asarray(rsi["m"]),
                                  mi.sum(0).reshape(p, 8))
    print(f"comm reduce_scatter pytree p={p} backend={backend} ok")

    # ---- plan keys normalize onto the resolved block count: auto
    # (n_blocks=None) and the explicit optimum share one plan/executor.
    auto_plan = comm.plan("broadcast", xs, root=root)
    assert comm.plan("broadcast", xs, n_blocks=auto_plan.n_blocks,
                     root=root) is auto_plan, "n_blocks key not normalized"

    # ---- allgatherv: per-leaf sizes pytree + one shared sizes list.
    sizes = {"u": [3 * j + 1 for j in range(p)], "v": [7] * p}
    vin = {"u": np.zeros((p, 3 * p), np.int32),
           "v": np.zeros((p, 9), np.float32)}
    for j in range(p):
        vin["u"][j, : sizes["u"][j]] = rng.integers(1, 99, size=sizes["u"][j])
        vin["v"][j, :7] = rng.normal(size=7)
    gv = comm.allgatherv({k: sharded(mesh, jnp.asarray(v))
                          for k, v in vin.items()}, sizes, n_blocks=2)
    for j in range(p):
        np.testing.assert_array_equal(np.asarray(gv["u"])[j, : sizes["u"][j]],
                                      vin["u"][j, : sizes["u"][j]])
        np.testing.assert_array_equal(np.asarray(gv["v"])[j, :7],
                                      vin["v"][j, :7])
    shared = comm.allgatherv({"v": sharded(mesh, jnp.asarray(vin["v"]))},
                             [7] * p, n_blocks=2)
    np.testing.assert_array_equal(np.asarray(shared["v"])[:, :7],
                                  vin["v"][:, :7])
    print(f"comm allgatherv pytree p={p} backend={backend} ok")

    # ---- shim equivalence: circulant_* resolves to the same plan cache.
    arr = sharded(mesh, jnp.asarray(state["w"]))
    a = circulant_broadcast(mesh, "data", arr, n_blocks=4, root=root,
                            backend=backend)
    b = comm.broadcast(arr, n_blocks=4, root=root)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(f"comm shim equivalence p={p} backend={backend} ok")


def check_hier(nodes, cores, backend="jnp"):
    """Two-level hierarchical collectives on a (nodes x cores) mesh:
    dict/mixed-dtype pytree payloads for broadcast / reduce / allreduce
    / allgather, certified against per-leaf NumPy references, with
    plan-cache identity and the composed round counts asserted."""
    from jax.sharding import NamedSharding
    from repro.core.hier import get_hier_comm, hier_rounds

    p = nodes * cores
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(nodes, cores),
                ("node", "core"))
    spec2d = NamedSharding(mesh, P(("node", "core")))
    hc = get_hier_comm(mesh, "node", "core", backend=backend)
    rng = np.random.default_rng(31)

    # ---- broadcast: dict pytree, mixed dtypes, ragged leaves, flat
    # root in the last node's last core.
    root = p - 1
    state = {
        "w": rng.normal(size=(p, 17, 3)).astype(np.float32),
        "b": rng.integers(0, 100, size=(p, 11)).astype(np.int32),
        "t": (rng.normal(size=(p, 5)).astype(jnp.bfloat16),),
    }
    xs = jax.tree.map(lambda a: jax.device_put(jnp.asarray(a), spec2d), state)
    plan = hc.plan("broadcast", xs, n_inter=2, n_intra=3, root=root)
    assert plan is hc.plan("broadcast", xs, n_inter=2, n_intra=3, root=root), \
        "hier plan cache lost identity"
    assert plan.rounds == hier_rounds("broadcast", nodes, cores, 2, 3)
    out = plan(xs)
    for k in ("w", "b"):
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.broadcast_to(state[k][root],
                                                state[k].shape))
    np.testing.assert_array_equal(
        np.asarray(out["t"][0], np.float32),
        np.broadcast_to(np.asarray(state["t"][0], np.float32)[root],
                        state["t"][0].shape))
    print(f"hier broadcast {nodes}x{cores} root={root} backend={backend} ok")

    # ---- reduce: bit-exact int sum at the root, zeros elsewhere; float
    # max bit-exact too.
    data = {"a": rng.integers(-50, 50, size=(p, 13)).astype(np.int32),
            "b": rng.integers(-50, 50, size=(p, 7, 2)).astype(np.int32)}
    ds = jax.tree.map(lambda a: jax.device_put(jnp.asarray(a), spec2d), data)
    rroot = p // 2
    red = hc.reduce(ds, n_inter=1, n_intra=2, root=rroot)
    np.testing.assert_array_equal(np.asarray(red["a"])[rroot],
                                  data["a"].sum(0))
    np.testing.assert_array_equal(np.asarray(red["b"])[rroot],
                                  data["b"].sum(0))
    for r in range(p):
        if r != rroot:
            assert not np.asarray(red["a"])[r].any(), f"rank {r} not zeroed"
    fdata = {"a": rng.normal(size=(p, 13)).astype(np.float32)}
    fs = jax.tree.map(lambda a: jax.device_put(jnp.asarray(a), spec2d), fdata)
    fred = hc.reduce(fs, n_inter=2, n_intra=2, root=0, op="max")
    np.testing.assert_array_equal(np.asarray(fred["a"])[0],
                                  fdata["a"].max(0))
    print(f"hier reduce {nodes}x{cores} backend={backend} ok")

    # ---- allreduce: every rank ends with the per-leaf reduction.
    ar = hc.allreduce(ds, n_inter=2, n_intra=1)
    for r in range(p):
        np.testing.assert_array_equal(np.asarray(ar["a"])[r],
                                      data["a"].sum(0))
        np.testing.assert_array_equal(np.asarray(ar["b"])[r],
                                      data["b"].sum(0))
    arp = hc.plan("allreduce", ds, n_inter=2, n_intra=1)
    assert arp.rounds == hier_rounds("allreduce", nodes, cores, 2, 1)
    print(f"hier allreduce {nodes}x{cores} backend={backend} ok")

    # ---- allgather: replicated rank-major result, mixed dtypes.
    g = {"x": rng.normal(size=(p * 6,)).astype(np.float32),
         "y": rng.integers(0, 9, size=(p, 4)).astype(np.int32)}
    gs = jax.tree.map(lambda a: jax.device_put(jnp.asarray(a), spec2d), g)
    got = hc.allgather(gs, n_inter=2, n_intra=2)
    np.testing.assert_array_equal(np.asarray(got["x"]), g["x"])
    np.testing.assert_array_equal(np.asarray(got["y"]), g["y"])
    print(f"hier allgather {nodes}x{cores} backend={backend} ok")

    # ---- degenerate embeddings: a 1 x p hier broadcast equals the flat
    # circulant broadcast over the same devices.
    mesh1 = Mesh(np.array(jax.devices()[:p]).reshape(1, p), ("node", "core"))
    spec1 = NamedSharding(mesh1, P(("node", "core")))
    h1 = get_hier_comm(mesh1, "node", "core", backend=backend)
    arr = jax.device_put(jnp.asarray(state["w"]), spec1)
    a = np.asarray(h1.broadcast(arr, n_intra=3, root=1))
    b = np.asarray(circulant_broadcast(mesh1, "core", arr, n_blocks=3,
                                       root=1, backend=backend))
    np.testing.assert_array_equal(a, b)
    print(f"hier degenerate 1x{p} == flat backend={backend} ok")


def check_analysis(p, nodes, backend="jnp"):
    """Static plan audit of real *device* plans: build CollectivePlan /
    HierPlan objects on a live mesh and run repro.analysis.planaudit on
    their statics (the host-plane CLI covers host plans; this covers
    the jitted flavour's closed-over tables)."""
    from repro.analysis import audit_plan
    from repro.core.comm import get_comm
    from repro.core.hier import get_hier_comm

    mesh = make_mesh(p)
    comm = get_comm(mesh, "data", backend=backend)
    rng = np.random.default_rng(41)
    xs = {"w": sharded(mesh, jnp.asarray(
        rng.normal(size=(p, 12)).astype(np.float32)))}
    for kind in ("broadcast", "allgather", "reduce", "allreduce"):
        rooted = kind in ("broadcast", "reduce")
        plan = comm.plan(kind, xs, n_blocks=3,
                         root=p - 1 if rooted else 0)
        rep = audit_plan(plan)
        assert rep.ok, f"device {kind} plan failed audit:\n{rep.summary()}"
        assert rep.checked > 0, f"device {kind} audit was vacuous"
        print(f"analysis device {kind} p={p} backend={backend} ok")
    qplan = comm.plan("quantized_allreduce",
                      {"g": sharded(mesh, jnp.asarray(
                          rng.normal(size=(p, 512)).astype(np.float32)))},
                      qblock=256)
    rep = audit_plan(qplan)
    assert rep.ok, f"device quantized plan failed audit:\n{rep.summary()}"
    print(f"analysis device quantized_allreduce p={p} ok")

    cores = p // nodes
    hmesh = Mesh(np.array(jax.devices()[:p]).reshape(nodes, cores),
                 ("node", "core"))
    hc = get_hier_comm(hmesh, "node", "core", backend=backend)
    spec2d = NamedSharding(hmesh, P(("node", "core")))
    hxs = {"w": jax.device_put(jnp.asarray(
        rng.normal(size=(p, 10)).astype(np.float32)), spec2d)}
    for kind in ("broadcast", "reduce", "allreduce", "allgather"):
        rooted = kind in ("broadcast", "reduce")
        hplan = hc.plan(kind, hxs, n_inter=2, n_intra=2,
                        root=p - 1 if rooted else 0)
        rep = audit_plan(hplan)
        assert rep.ok, f"device hier {kind} failed audit:\n{rep.summary()}"
        print(f"analysis device hier {kind} {nodes}x{cores} ok")


def check_ring(p, elems=16):
    mesh = make_mesh(p)
    data = np.arange(p * elems, dtype=np.float32)
    x = sharded(mesh, jnp.asarray(data))
    out = jax.jit(lambda a: ring_allgather(mesh, "data", a))(x)
    np.testing.assert_allclose(np.asarray(out), data)
    print(f"ring p={p} ok")


def main(what, p, backend="jnp", nodes=2):
    if len(jax.devices()) < p:
        # Graceful skip (e.g. a backend that ignores the host-device
        # forcing flag): the caller maps this to pytest.skip.
        print(f"SKIP only {len(jax.devices())} device(s) available, need {p}")
        return
    if what == "analysis":
        assert p % nodes == 0, f"nodes={nodes} must divide p={p}"
        check_analysis(p, nodes, backend=backend)
        print("ALL OK")
        return
    if what == "hier":
        assert p % nodes == 0, f"nodes={nodes} must divide p={p}"
        check_hier(nodes, p // nodes, backend=backend)
        print("ALL OK")
        return
    if what in ("broadcast", "all"):
        for n in (1, 2, 3, 5, 8):
            check_broadcast(p, n, root=0, backend=backend)
        check_broadcast(p, 4, root=p // 2, backend=backend)
        check_broadcast(p, 4, root=p - 1, backend=backend)
        check_broadcast(p, 3, root=0, dtype=jnp.bfloat16, backend=backend)
        check_broadcast(p, 3, root=0, dtype=jnp.int32, backend=backend)
    if what in ("allgather", "all"):
        for n in (1, 2, 5, 8):
            check_allgather(p, n, backend=backend)
        check_allgather(p, 3, dtype=jnp.bfloat16, backend=backend)
    if what in ("allgatherv", "all"):
        rng = np.random.default_rng(3)
        check_allgatherv(p, 2, [10 * ((j % 3)) + 1 for j in range(p)],
                         backend=backend)
        # degenerate: one rank has everything
        check_allgatherv(p, 3, [600] + [1] * (p - 1), backend=backend)
        check_allgatherv(p, 2, list(rng.integers(1, 50, size=p)),
                         backend=backend)
    if what in ("ring", "all"):
        check_ring(p)
    if what in ("compressed", "all"):
        check_compressed_allreduce(p, backend=backend)
    if what == "gradsync":
        check_gradsync(p, backend=backend)
    if what == "gradsync_stream":
        check_gradsync_stream(p, backend=backend)
    if what in ("overlap", "all"):
        check_overlap(p, backend=backend)
    if what in ("restore", "all"):
        check_restore_broadcast(p)
    if what in ("reducescatter", "all"):
        check_reduce_scatter(p)
    if what in ("reduce", "all"):
        check_reduce(p, backend=backend)
    if what in ("allreduce", "all"):
        check_allreduce(p, backend=backend)
    if what in ("allbroadcast", "all"):
        check_allbroadcast(p)
    if what in ("comm", "all"):
        check_comm(p, backend=backend)
    print("ALL OK")


if __name__ == "__main__":
    main(what, p, backend, nodes)
