"""The overlapped (double-buffered) round executor.

Three layers, mirroring how the mode is built:

  1. kernel level: the staged round steps (``shuffle_staged``,
     ``acc_shuffle_staged``) agree bit-exactly across backends and with
     their defining identity vs the sequential steps -- including the
     bypass case (send what is being received this round) the staging
     exists for;
  2. plan level: ``overlap=True`` host plans are bit-exact against the
     sequential executor for every supported kind over the edge-p grid,
     distinct cached objects carrying the flag, and the unsupported
     kinds are rejected at plan time;
  3. audit level: the static auditor accepts the double-buffered
     statics over the sweep grid, rejects overlap statics for
     unsupported kinds, and flags a plan whose executor mode disagrees
     with its audited tables.

The multidevice rows (real ``ppermute`` exchange, both backends, plus
the streamed trainer parity check) go through tests/mp_worker.py.
"""

import numpy as np
import pytest

from conftest import run_worker
from repro.analysis.planaudit import (
    audit_kind,
    audit_plan,
    OVERLAP_KINDS,
    statics_for_kind,
)
from repro.core.comm import host_plan
from repro.core.roundstep import get_round_step

RNG = np.random.default_rng(11)

BACKENDS = ["jnp", "pallas"]
# host_plan needs p >= 2 (p=1 never plans a round loop: the device-plan
# fast path returns the payload untouched, covered in test_comm.py).
EDGE_PS = [2, 3, 11, 36]


# ------------------------------------------------------- kernel level


@pytest.mark.parametrize("R,ns,bs", [(1, 4, 8), (8, 6, 16)])
def test_shuffle_staged_backends_and_identity(R, ns, bs):
    import jax.numpy as jnp

    buf = jnp.asarray(RNG.normal(size=(R, ns, bs)), jnp.float32)
    msg = jnp.asarray(RNG.normal(size=(R, bs)), jnp.float32)
    recv = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    send = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    send = send.at[0].set(recv[0])  # the bypass case staging exists for
    jstep, pstep = get_round_step("jnp"), get_round_step("pallas")
    pre = jstep.pack(buf, send)
    jb, jm = jstep.shuffle_staged(buf, msg, pre, recv, send)
    pb, pm = pstep.shuffle_staged(buf, msg, pre, recv, send)
    np.testing.assert_array_equal(np.asarray(jb), np.asarray(pb))
    np.testing.assert_array_equal(np.asarray(jm), np.asarray(pm))
    # defining identity: staged(pre-packed next block) == sequential
    sb, sm = jstep.shuffle(buf, msg, recv, send)
    np.testing.assert_array_equal(np.asarray(jb), np.asarray(sb))
    np.testing.assert_array_equal(np.asarray(jm), np.asarray(sm))


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("R,ns,bs", [(1, 4, 8), (8, 6, 16)])
def test_acc_shuffle_staged_backends_and_identity(op, R, ns, bs):
    import jax.numpy as jnp

    buf = jnp.asarray(RNG.normal(size=(R, ns, bs)), jnp.float32)
    msg = jnp.asarray(RNG.normal(size=(R, bs)), jnp.float32)
    acc = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    fwd = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    fwd = fwd.at[0].set(acc[0])  # capture-after-accumulate bypass
    jstep, pstep = get_round_step("jnp"), get_round_step("pallas")
    pre = jstep.pack(buf, fwd)
    jb, jm = jstep.acc_shuffle_staged(buf, msg, pre, acc, fwd, op=op)
    pb, pm = pstep.acc_shuffle_staged(buf, msg, pre, acc, fwd, op=op)
    np.testing.assert_array_equal(np.asarray(jb), np.asarray(pb))
    np.testing.assert_array_equal(np.asarray(jm), np.asarray(pm))
    sb, sm = jstep.acc_shuffle(buf, msg, acc, fwd, op=op)
    np.testing.assert_array_equal(np.asarray(jb), np.asarray(sb))
    np.testing.assert_array_equal(np.asarray(jm), np.asarray(sm))


# --------------------------------------------------------- plan level


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", EDGE_PS)
def test_host_overlap_bitexact(backend, p):
    """Every supported kind: overlapped host executor == sequential,
    bit for bit (float payloads -- same accumulation order too)."""
    rng = np.random.default_rng(p)
    n, bs = 3, 5

    bvals = rng.normal(size=(n, bs))
    seq = host_plan("broadcast", p, n, root=p - 1, backend=backend)
    ovl = host_plan("broadcast", p, n, root=p - 1, backend=backend,
                    overlap=True)
    assert ovl is not seq and ovl.overlap and not seq.overlap
    np.testing.assert_array_equal(seq.run(bvals), ovl.run(bvals))

    gvals = rng.normal(size=(p, n, bs))
    np.testing.assert_array_equal(
        host_plan("allgather", p, n, backend=backend).run(gvals),
        host_plan("allgather", p, n, backend=backend,
                  overlap=True).run(gvals))

    for op in ("sum", "max"):
        np.testing.assert_array_equal(
            host_plan("reduce", p, n, root=p - 1, op=op,
                      backend=backend).run(gvals),
            host_plan("reduce", p, n, root=p - 1, op=op, backend=backend,
                      overlap=True).run(gvals))


def test_overlap_p1_fast_path():
    """p=1 never plans a round loop: the overlapped device plan takes
    the same identity fast path as the sequential one."""
    import jax
    from jax.sharding import Mesh

    from repro.core.comm import get_comm

    comm = get_comm(Mesh(np.array(jax.devices()[:1]), ("data",)), "data")
    x = {"w": np.arange(6, dtype=np.float32).reshape(1, 6)}
    for kind in ("broadcast", "allgather", "reduce", "allreduce"):
        plan = comm.plan(kind, x, overlap=True)
        assert plan.overlap
        np.testing.assert_array_equal(plan(x)["w"], x["w"])


def test_host_overlap_plan_identity_cached():
    a = host_plan("broadcast", 5, 3, overlap=True)
    b = host_plan("broadcast", 5, 3, overlap=True)
    assert a is b  # same cache contract as sequential plans


def test_host_overlap_unsupported_kind_rejected():
    with pytest.raises(ValueError, match="overlap"):
        host_plan("quantized_allreduce", 4, 3, overlap=True)


# -------------------------------------------------------- audit level


@pytest.mark.parametrize("kind", OVERLAP_KINDS)
def test_audit_accepts_overlap_statics(kind):
    for p in (2, 7, 36):
        rep = audit_kind(kind, p, 4, root=p - 1, overlap=True)
        assert rep.ok, rep.findings
        assert rep.checked > 0


@pytest.mark.parametrize("kind", ["allgatherv", "quantized_allreduce"])
def test_audit_rejects_unsupported_overlap_statics(kind):
    with pytest.raises(ValueError, match="overlap"):
        statics_for_kind(kind, 4, 4, overlap=True)


def test_audit_plan_flags_overlap_mismatch():
    """A plan claiming the sequential executor over double-buffered
    tables (or vice versa) is an audit finding, not a silent pass."""
    from types import SimpleNamespace

    statics = statics_for_kind("broadcast", 5, 3, overlap=True)
    rep = audit_plan(SimpleNamespace(statics=statics, overlap=False))
    assert not rep.ok
    assert any(f.check == "overlap-flag" for f in rep.findings)
    # flag agreement on real plans, both modes
    for overlap in (False, True):
        rep = audit_plan(host_plan("broadcast", 5, 3, overlap=overlap))
        assert rep.ok, rep.findings


# -------------------------------------------------- multidevice level


@pytest.mark.multidevice
@pytest.mark.parametrize("p,backend", [(2, "jnp"), (4, "jnp"),
                                       (3, "pallas")])
def test_overlap_device_plans_bitexact(p, backend):
    """Device plans with the real ppermute exchange: overlap=True is
    bit-exact vs sequential for every supported kind, and the
    unsupported kinds raise at plan time."""
    run_worker("overlap", p, backend)


@pytest.mark.multidevice
def test_trainer_streamed_grad_sync_parity():
    """stream_grad_sync=True (per-bucket collectives launched from the
    backward pass) trains within quantization-order divergence of the
    single combined sync, with and without microbatching."""
    run_worker("gradsync_stream", 2)
