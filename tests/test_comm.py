"""Tests for the plan/execute communicator API (repro.core.comm).

In-process tests cover the host-side machinery that needs no devices:
payload specs, plan-cache identity, spec validation, the frozen
CommModel default, the deprecated legacy aliases, the p=1 fast path
(a 1-device mesh works in the main process), and the host data-plane
certification grid over both round-step backends.

The multidevice-marked tests run ``tests/mp_worker.py comm`` in a
subprocess with a forced p-device host platform: pytree payloads
(dict/tuple trees, mixed dtypes, ragged leaves) for all six collective
kinds, certified bit-exact against per-leaf NumPy references on both
the ``jnp`` and ``pallas`` data planes.
"""

import os
import sys

import numpy as np
import pytest

from conftest import run_worker

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


# ------------------------------------------------------- host-side tests


def _mesh1():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]), ("data",))


def test_commmodel_frozen_and_hashable():
    import dataclasses

    from repro.core.costmodel import DEFAULT_MODEL, CommModel

    assert isinstance(DEFAULT_MODEL, CommModel)
    assert hash(DEFAULT_MODEL) == hash(CommModel())
    assert DEFAULT_MODEL == CommModel()
    with pytest.raises(dataclasses.FrozenInstanceError):
        DEFAULT_MODEL.alpha = 1.0  # type: ignore[misc]
    # The shared default really is the module constant (plan-cache keys
    # built from default-model calls collide onto one entry).
    from repro.core.comm import CirculantComm

    comm = CirculantComm(mesh=_mesh1(), axis_name="data")
    assert comm.model is DEFAULT_MODEL


def test_legacy_aliases_warn_and_resolve():
    from repro.core.collectives import CirculantTables, build_tables
    from repro.core.engine import get_bundle

    with pytest.warns(DeprecationWarning, match="get_bundle"):
        b = CirculantTables(8)
    assert b is get_bundle(8)
    with pytest.warns(DeprecationWarning, match="get_bundle"):
        b = build_tables(12)
    assert b is get_bundle(12)


def test_payload_spec_hashable_and_stable():
    import jax

    from repro.core.comm import payload_spec

    tree = {"w": np.zeros((4, 3), np.float32),
            "b": (np.zeros((4,), np.int32),)}
    s1 = payload_spec(tree)
    s2 = payload_spec({"w": jax.ShapeDtypeStruct((4, 3), np.float32),
                       "b": (jax.ShapeDtypeStruct((4,), np.int32),)})
    assert s1 == s2 and hash(s1) == hash(s2)
    assert payload_spec(s1) is s1
    assert s1.num_leaves == 2
    s3 = payload_spec({"w": np.zeros((4, 3), np.float64),
                       "b": (np.zeros((4,), np.int32),)})
    assert s3 != s1


def test_get_comm_cached_identity():
    from repro.core.comm import get_comm
    from repro.core.costmodel import CommModel

    mesh = _mesh1()
    c1 = get_comm(mesh, "data")
    assert c1 is get_comm(mesh, "data")
    assert c1 is not get_comm(mesh, "data", backend="pallas")
    assert c1 is not get_comm(mesh, "data", model=CommModel(alpha=5e-6))


def test_comm_validates_axis_and_backend():
    from repro.core.comm import CirculantComm

    with pytest.raises(ValueError, match="axis"):
        CirculantComm(mesh=_mesh1(), axis_name="model")
    with pytest.raises(ValueError, match="backend"):
        CirculantComm(mesh=_mesh1(), axis_name="data", backend="cuda")


def test_plan_cache_identity_and_kind_canonicalization():
    from repro.core.comm import get_comm

    comm = get_comm(_mesh1(), "data")
    x = {"a": np.zeros((1, 8), np.float32)}
    p1 = comm.plan("broadcast", x, n_blocks=2)
    assert p1 is comm.plan("broadcast", x, n_blocks=2)
    # n_blocks=None resolves before keying: auto and the explicit
    # resolved value share one plan (one executor)
    auto = comm.plan("broadcast", x)
    assert comm.plan("broadcast", x, n_blocks=auto.n_blocks) is auto
    # allbroadcast canonicalizes onto the allgather plan
    g = np.zeros((1, 8), np.float32)
    assert comm.plan("allbroadcast", g) is comm.plan("allgather", g)
    with pytest.raises(ValueError, match="kind"):
        comm.plan("gossip", x)
    # arguments that don't apply to the kind are rejected, not dropped
    with pytest.raises(ValueError, match="root"):
        comm.plan("allgather", g, root=1)
    with pytest.raises(ValueError, match="op"):
        comm.plan("broadcast", x, op="max")
    with pytest.raises(ValueError, match="op"):
        comm.plan("reduce_scatter", x, op="max")
    with pytest.raises(ValueError, match="sizes"):
        comm.plan("reduce", x, sizes=[1])


def test_p1_fast_path_identity_pytree():
    import jax

    from repro.core.comm import get_comm

    comm = get_comm(_mesh1(), "data")
    state = {"w": np.arange(12, dtype=np.float32).reshape(1, 12),
             "b": (np.arange(5, dtype=np.int32).reshape(1, 5),)}
    for kind in ("broadcast", "reduce", "allreduce"):
        plan = comm.plan(kind, state, n_blocks=3)
        assert plan.p == 1 and plan.rounds == 0
        out = plan(state)
        assert jax.tree.structure(out) == jax.tree.structure(state)
        np.testing.assert_array_equal(out["w"], state["w"])
        np.testing.assert_array_equal(out["b"][0], state["b"][0])
    # the method shorthands hit the same fast path
    out = comm.allgather(state)
    np.testing.assert_array_equal(out["w"], state["w"])
    out = comm.allgatherv({"v": np.zeros((1, 4), np.float32)}, [4])
    assert out["v"].shape == (1, 4)
    # wrong-length sizes are rejected even on the p=1 fast path, so
    # single-device development catches them before a real mesh does
    with pytest.raises(ValueError, match="length"):
        comm.allgatherv({"v": np.zeros((1, 4), np.float32)}, [4, 4])
    out = comm.reduce_scatter({"m": np.zeros((1, 6), np.float32)})
    assert out["m"].shape == (1, 6)


def test_plan_rejects_mismatched_payloads():
    from repro.core.comm import get_comm

    comm = get_comm(_mesh1(), "data")
    x = {"a": np.zeros((1, 8), np.float32)}
    plan = comm.plan("broadcast", x, n_blocks=2)
    with pytest.raises(ValueError, match="tree"):
        plan({"b": np.zeros((1, 8), np.float32)})
    with pytest.raises(ValueError, match="leaf"):
        plan({"a": np.zeros((1, 9), np.float32)})
    with pytest.raises(ValueError, match="leaf"):
        plan({"a": np.zeros((1, 8), np.int32)})


def test_plan_validates_shapes_at_build():
    """Build-time validation: bad payload shapes fail at plan() time for
    p > 1 specs (exercised via plan construction on a fake 2-rank spec
    through the resolvers; the mesh itself has one device, so we call
    the resolvers directly)."""
    from repro.core.comm import (
        _resolve_allgather,
        _resolve_allgatherv,
        _resolve_broadcast,
        _resolve_reduce_scatter,
        payload_spec,
    )
    from repro.core.costmodel import DEFAULT_MODEL, optimal_num_blocks_bcast

    spec = payload_spec({"a": np.zeros((3, 4), np.float32)})
    with pytest.raises(ValueError, match="leading axis"):
        _resolve_broadcast(spec, 2, None, DEFAULT_MODEL,
                           optimal_num_blocks_bcast)
    with pytest.raises(ValueError, match="divisible"):
        _resolve_allgather(spec, 2, None, DEFAULT_MODEL)
    spec2 = payload_spec({"a": np.zeros((2, 5), np.float32)})
    with pytest.raises(ValueError, match="divisible"):
        _resolve_reduce_scatter(spec2, 2, None, DEFAULT_MODEL)
    with pytest.raises(ValueError, match="out of range"):
        _resolve_allgatherv(spec2, 2, None, DEFAULT_MODEL, ((3, 9),))
    # matching specs resolve and respect explicit n_blocks
    assert _resolve_broadcast(spec2, 2, 3, DEFAULT_MODEL,
                              optimal_num_blocks_bcast) == 3


def test_quantized_allreduce_plan_validation():
    """quantized_allreduce plan: op/dtype/qblock constraints, the p==1
    (sums, zero-errors) fast path, and qblock participating in the
    plan cache key."""
    from repro.core.comm import _resolve_quantized, get_comm, payload_spec
    from repro.core.costmodel import DEFAULT_MODEL

    comm = get_comm(_mesh1(), "data")
    x = {"g": np.ones((1, 600), np.float32)}
    plan = comm.plan("quantized_allreduce", x, n_blocks=2, qblock=8)
    assert plan.qblock == 8
    assert comm.plan("quantized_allreduce", x, n_blocks=2, qblock=8) is plan
    assert comm.plan("quantized_allreduce", x, n_blocks=2,
                     qblock=16) is not plan
    # p == 1: identity sums + zero error state, same (sums, errs) pair
    sums, errs = plan(x)
    np.testing.assert_array_equal(sums["g"], x["g"])
    np.testing.assert_array_equal(errs["g"], np.zeros_like(x["g"]))
    # validation: sum-only, f32-only, qblock only for this kind
    with pytest.raises(ValueError, match="sums"):
        comm.plan("quantized_allreduce", x, op="max")
    with pytest.raises(ValueError, match="qblock"):
        comm.plan("allreduce", x, qblock=8)
    with pytest.raises(ValueError, match="qblock"):
        comm.plan("quantized_allreduce", x, qblock=0)
    spec_bf16 = payload_spec({"g": np.zeros((2, 8), np.float32)
                              .astype(np.float16)})
    with pytest.raises(ValueError, match="float32"):
        _resolve_quantized(spec_bf16, 2, None, DEFAULT_MODEL, 8)
    spec_bad = payload_spec({"g": np.zeros((3, 8), np.float32)})
    with pytest.raises(ValueError, match="leading axis"):
        _resolve_quantized(spec_bad, 2, None, DEFAULT_MODEL, 8)
    # n clamps so every schedule block spans >= one quantization block
    spec_small = payload_spec({"g": np.zeros((2, 12), np.float32)})
    n = _resolve_quantized(spec_small, 2, 64, DEFAULT_MODEL, 8)
    assert n <= 2, n
    # shorthand returns the pair too
    sums2, errs2 = comm.quantized_allreduce(x["g"], n_blocks=2, qblock=8)
    np.testing.assert_array_equal(sums2, x["g"])


def test_allgatherv_sizes_canonicalization():
    from repro.core.comm import _canon_sizes, payload_spec

    spec = payload_spec({"u": np.zeros((2, 6), np.int32),
                         "v": np.zeros((2, 4), np.float32)})
    # one shared per-rank list fans out to every leaf
    assert _canon_sizes(spec, [5, 2]) == ((5, 2), (5, 2))
    # a matching pytree of per-rank lists stays per-leaf
    assert _canon_sizes(spec, {"u": [5, 2], "v": (4, 1)}) == ((5, 2), (4, 1))
    # numpy arrays work as size vectors
    assert _canon_sizes(spec, np.asarray([1, 1])) == ((1, 1), (1, 1))
    with pytest.raises(ValueError, match="sizes"):
        _canon_sizes(spec, {"u": [5, 2]})


# --------------------------------------------- host data-plane plans


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_host_plan_certification_grid(backend):
    """The simulator's backend certification (now routed through cached
    host plans) holds over a (p, n, root, op) grid for this backend."""
    from repro.core import simulate_allgather, simulate_broadcast, simulate_reduce

    for p in (2, 5, 8):
        for n in (1, 3):
            simulate_broadcast(p, n, root=p - 1, backend=backend)
            simulate_allgather(p, n, backend=backend)
            simulate_reduce(p, n, root=p // 2, op="sum", backend=backend)
    simulate_reduce(5, 4, op="max", backend=backend)


def test_host_plan_cached_identity_and_reuse():
    from repro.core.comm import host_plan

    hp = host_plan("broadcast", 11, 4, backend="jnp")
    assert hp is host_plan("broadcast", 11, 4, backend="jnp")
    assert hp is not host_plan("broadcast", 11, 4, backend="pallas")
    got = hp.run(np.arange(4, dtype=np.int64))
    assert got.shape == (11, 4, 1)
    for r in range(11):
        np.testing.assert_array_equal(got[r].reshape(-1), np.arange(4))
    with pytest.raises(ValueError, match="kind"):
        host_plan("gossip", 4, 2)


def test_host_plan_slot_tables_are_shared_and_immutable():
    from repro.core.comm import host_plan
    from repro.core.engine import get_bundle
    from repro.core.roundstep import broadcast_slot_plan, reduce_slot_plan

    hp = host_plan("broadcast", 9, 3)
    recv, send, ks = broadcast_slot_plan(get_bundle(9), 3)
    assert hp.slots[0] is recv and hp.slots[1] is send
    with pytest.raises(ValueError):
        recv[0, 0] = 0  # immutable, shared across plans
    fwd, acc, ks2 = reduce_slot_plan(get_bundle(9), 3)
    assert (fwd[:, 0] == 3 + 1).all()  # root pinned to the identity slot
    with pytest.raises(ValueError):
        fwd[0, 0] = 0


@pytest.mark.plan_cache_mutating
def test_plan_cache_clear_and_info():
    from repro.core.comm import host_plan
    from repro.core.engine import plan_cache_clear, plan_cache_info

    host_plan("broadcast", 13, 2)
    assert plan_cache_info()["size"] > 0
    before = plan_cache_info()["size"]
    hp1 = host_plan("broadcast", 13, 2)
    assert plan_cache_info()["size"] == before  # hit, not a new entry
    plan_cache_clear()
    assert plan_cache_info() == {"size": 0, "hits": 0, "misses": 0}
    hp2 = host_plan("broadcast", 13, 2)
    assert hp2 is not hp1  # rebuilt after the clear


@pytest.mark.plan_cache_mutating
def test_plan_cache_limit_lru():
    """plan_cache_limit(k): k-most-recently-USED retention -- hits
    refresh recency, insertions evict the oldest, identity holds while
    resident, and plan_cache_limit(None) restores the unbounded
    default."""
    from repro.core.engine import (cached_plan, plan_cache_clear,
                                   plan_cache_info, plan_cache_limit)

    plan_cache_clear()
    assert plan_cache_limit() is None  # unbounded default
    try:
        plan_cache_limit(2)
        a = cached_plan(("lru", 1), object)
        b = cached_plan(("lru", 2), object)
        assert cached_plan(("lru", 1), object) is a  # hit refreshes 1
        cached_plan(("lru", 3), object)              # evicts 2, not 1
        assert plan_cache_info()["size"] == 2
        assert cached_plan(("lru", 1), object) is a  # still resident
        assert cached_plan(("lru", 2), object) is not b  # evicted, rebuilt
        # lowering the bound evicts immediately, oldest first
        plan_cache_limit(1)
        assert plan_cache_info()["size"] == 1
        assert cached_plan(("lru", 2), object) is not None  # survivor = MRU
        # removing the bound keeps entries and stops evicting
        plan_cache_limit(None)
        for i in range(8):
            cached_plan(("lru", "wide", i), object)
        assert plan_cache_info()["size"] == 9
        with pytest.raises(ValueError, match=">= 1"):
            plan_cache_limit(0)
    finally:
        plan_cache_limit(None)
        plan_cache_clear()


def test_optimal_blocks_never_outnumber_payload():
    """Block-count optima are clamped to [1, max(1, m)]: a block beyond
    the payload unit count is pure padding (moves nothing, costs a
    round).  Swept over p x m grids including the degenerate regimes
    (tiny m, huge analytic optima, nonfinite model output)."""
    import math

    from repro.core.costmodel import (
        CommModel,
        DEFAULT_MODEL,
        optimal_hier_blocks,
        optimal_num_blocks_allgather,
        optimal_num_blocks_allreduce,
        optimal_num_blocks_bcast,
        optimal_num_blocks_reduce,
    )

    fns = (optimal_num_blocks_bcast, optimal_num_blocks_reduce,
           optimal_num_blocks_allreduce, optimal_num_blocks_allgather)
    # near-free latency drives the analytic optimum sqrt(q beta m/alpha)
    # far past m; the clamp must hold for it just like the default model
    degenerate = CommModel(alpha=1e-30, beta=1.0)
    for model in (DEFAULT_MODEL, degenerate):
        for p in (1, 2, 5, 36, 1024):
            for m in (0.0, 0.5, 1.0, 2.0, 3.7, 10.0, 4e6):
                for fn in fns:
                    n = fn(p, m, model)
                    assert 1 <= n <= max(1, int(m)), (fn.__name__, p, m, n)
    # nonfinite model output degrades to the safe minimum, never raises
    assert optimal_num_blocks_bcast(8, float("nan"), DEFAULT_MODEL) == 1
    nan_model = CommModel(alpha=float("nan"), beta=1.0)
    assert optimal_num_blocks_bcast(8, 100.0, nan_model) == 1
    # hierarchical: each level clamps against its own payload volume
    n_inter, n_intra = optimal_hier_blocks(36, 32, 2.0, 4e6,
                                           degenerate, degenerate)
    assert 1 <= n_inter <= 2 and 1 <= n_intra <= int(4e6)
    for kind in ("broadcast", "reduce", "allreduce", "allgather"):
        ni, nc = optimal_hier_blocks(6, 4, 0.5, 0.5, kind=kind)
        assert (ni, nc) == (1, 1)
    assert all(map(math.isfinite, optimal_hier_blocks(2, 2, 1.0, 1.0)))


def test_deprecated_aliases_still_in_collectives_all():
    """The shim surface stays importable: everything the seed exported
    from collectives still resolves."""
    from repro.core import collectives

    for name in ("circulant_broadcast", "circulant_allgather",
                 "circulant_allgatherv", "circulant_allbroadcast",
                 "circulant_reduce", "circulant_allreduce",
                 "ring_allgather", "CirculantTables", "build_tables"):
        assert hasattr(collectives, name), name
        assert name in collectives.__all__


# --------------------------------------------------- multidevice grid


@pytest.mark.multidevice
@pytest.mark.parametrize("p", [2, 5, 8])
def test_comm_pytree_multidevice(p):
    """Pytree payloads (dict/tuple, mixed dtypes, ragged leaves) for all
    six kinds vs per-leaf NumPy references on the jnp data plane."""
    run_worker("comm", p)


@pytest.mark.multidevice
def test_comm_pytree_multidevice_pallas():
    """The same grid through the fused Pallas (interpret) data plane."""
    run_worker("comm", 5, backend="pallas")