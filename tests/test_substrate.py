"""Substrate tests: checkpoint/restart, elastic resharding, data pipeline
determinism, optimizer behavior, serve loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models.transformer import init_params
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


# ------------------------------------------------------------ checkpoint


def _tiny_state():
    cfg = get_config("qwen2-0.5b", smoke=True)
    tcfg = TrainConfig(microbatches=1, opt=AdamWConfig(lr=1e-3, warmup_steps=1))
    return cfg, tcfg, init_train_state(cfg, tcfg, jax.random.PRNGKey(0))


def test_checkpoint_save_restore_roundtrip(tmp_path):
    cfg, tcfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, state, extra={"data_step": 7}, block=True)
    step, restored, extra = mgr.restore_latest(state)
    assert step == 7 and extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    cfg, tcfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, block=True)
    assert mgr.list_steps() == [3, 4]
    # torn checkpoint (no manifest) must be ignored
    os.makedirs(tmp_path / "step_0000000099")
    assert mgr.list_steps() == [3, 4]


def test_failure_recovery_resumes_identically(tmp_path):
    """Train 4 steps; 'crash' after 2; restore and continue -> states match."""
    cfg, tcfg, state = _tiny_state()
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))

    mgr = CheckpointManager(str(tmp_path), keep=3)
    s = state
    for i in range(4):
        s, _ = step_fn(s, data.batch_at(i))
        if i == 1:
            mgr.save(2, s, extra={"data_step": 2}, block=True)
    final_uninterrupted = s

    # crash + restore
    step0, s2, extra = mgr.restore_latest(state)
    assert step0 == 2
    for i in range(int(extra["data_step"]), 4):
        s2, _ = step_fn(s2, data.batch_at(i))
    for a, b in zip(jax.tree.leaves(final_uninterrupted), jax.tree.leaves(s2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


def test_elastic_restart_different_shard_count(tmp_path):
    """Checkpoints are global: a 4-shard run restores into a 2-shard run
    and the global batch stream stays identical (elastic resize)."""
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    four = [SyntheticLM(cfg, shard=i, num_shards=4) for i in range(4)]
    two = [SyntheticLM(cfg, shard=i, num_shards=2) for i in range(2)]
    b4 = np.concatenate([d.batch_at(5)["tokens"] for d in four])
    b2 = np.concatenate([d.batch_at(5)["tokens"] for d in two])
    assert b4.shape == b2.shape == (8, 8)
    # shard-count independence requires shard-keyed PRNG: rows differ in
    # order across shardings but the multiset of rows is stable per shard
    # count; what MUST hold is determinism per (seed, step, shard):
    again = np.concatenate([d.batch_at(5)["tokens"] for d in four])
    np.testing.assert_array_equal(b4, again)


# ------------------------------------------------------------------ data


def test_data_determinism_and_resume():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4)
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg)
    it1 = iter(d1)
    for _ in range(3):
        next(it1)
    d2.load_state_dict({"step": 3, "seed": 0})
    np.testing.assert_array_equal(next(iter(d2))["tokens"], next(it1)["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()


def test_data_zipf_distribution():
    cfg = DataConfig(vocab=1000, seq_len=512, global_batch=8)
    toks = SyntheticLM(cfg).batch_at(0)["tokens"].ravel()
    # rank 0 must be much more frequent than rank 100
    c0 = (toks == 0).sum()
    c100 = (toks == 100).sum()
    assert c0 > 5 * max(c100, 1)


def test_prefetcher():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(iter(SyntheticLM(cfg)), depth=2)
    batches = [next(pf) for _ in range(5)]
    assert len(batches) == 5
    pf.close()


# ------------------------------------------------------------- optimizer


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(cfg, params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_clip_norm():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(cfg, params)
    _, _, m = apply_updates(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_adamw_bf16_moments_roundtrip():
    cfg = AdamWConfig(lr=1e-2, moment_dtype="bfloat16")
    params = {"w": jnp.ones(8)}
    state = init_opt_state(cfg, params)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    p2, s2, _ = apply_updates(cfg, params, {"w": jnp.ones(8)}, state)
    assert p2["w"].dtype == params["w"].dtype


# ----------------------------------------------------------------- serve


def test_serve_loop_continuous_batching():
    from repro.serve.engine import Request, ServeLoop

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, batch_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4) for i in range(5)]
    for r in reqs:
        loop.submit(r)
    loop.run(max_steps=200)
    for r in reqs:
        assert r.done and len(r.out) == 4, (r.rid, r.out)
        assert all(0 <= t < cfg.vocab for t in r.out)
