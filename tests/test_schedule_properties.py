"""Property-based schedule conformance suite for the whole collective family.

Systematic replacement for spot checks: for sampled p in [1, 512] (plus a
deterministic edge list) and ALL ranks, assert

  * Correctness Conditions 3 & 4 (paper §2.1), forward AND reversed
    (the reduction reading of arXiv:2407.18004),
  * the send-table gather identity send[r][k] == recv[(r + skip[k]) % p][k]
    (Proposition 4 / Condition 2),
  * the permutation property of each round: round k's communication is
    the rotation r -> (r + skip[k]) % p, a perfect matching (every rank
    sends exactly one and receives exactly one message),
  * engine-vs-reference legacy equivalence: the O(log p) engine tables
    match the O(log^2 p)/O(log^3 p) legacy constructions bit-for-bit,
  * rooted bundles are exactly the row rotation of the root-0 tables and
    reversed tables are aliases of the forward ones (one cache entry
    serves the family -- no second table build).

Runs through tests/_hypothesis_compat.py, so it works with or without
hypothesis installed (the fallback runs a deterministic sample).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.engine import get_bundle
from repro.core.reference import (
    recv_schedule_legacy,
    send_schedule_from_recv,
    send_schedule_legacy,
)
from repro.core.schedule import baseblock, ceil_log2
from repro.core.verify import (
    check_condition_3,
    check_condition_4,
    check_reversed_condition_3,
    check_reversed_condition_4,
)

# Boundary-heavy deterministic coverage: powers of two +-1, the paper's
# p=11/16/17/36 worked examples, and the sampling range endpoints.
EDGE_PS = [1, 2, 3, 4, 5, 7, 8, 11, 16, 17, 31, 32, 33, 36, 63, 64,
           127, 128, 129, 255, 256, 257, 511, 512]

# Full legacy (O(log^3 p)) cross-check for every rank is quadratic-ish in
# practice; above this p a deterministic stride subset of ranks is used.
LEGACY_FULL_P = 128
LEGACY_SAMPLE_RANKS = 64


def assert_family_conformance(p: int) -> None:
    """All per-rank schedule properties for one axis size p."""
    bundle = get_bundle(p)
    q, skip = bundle.q, bundle.skips
    recv, send = bundle.recv, bundle.send

    assert recv.shape == send.shape == (p, q)

    # --- Conditions 3 & 4, forward and reversed, for every rank.
    for r in range(p):
        b = baseblock(r, skip, q)
        assert check_condition_3(bundle.recv_row(r), b, q), (p, r)
        assert check_reversed_condition_3(bundle.rev_send_row(r), b, q), (p, r)
        if r == 0:
            assert bundle.send_row(0) == list(range(q))
            assert bundle.rev_recv_row(0) == list(range(q))
        else:
            assert check_condition_4(
                bundle.recv_row(r), bundle.send_row(r), b, q
            ), (p, r)
            assert check_reversed_condition_4(
                bundle.rev_recv_row(r), bundle.rev_send_row(r), b, q
            ), (p, r)

    # --- Send-table gather identity (Prop. 4), vectorized over all ranks.
    if q:
        ranks = np.arange(p)[:, None]
        to = (ranks + np.asarray(skip[:q])[None, :]) % p
        assert np.array_equal(send, np.take_along_axis(recv, to, axis=0)), p

    # --- Permutation property of each round: the rotation by skip[k] is a
    # bijection on ranks, and in/out neighbor tables are mutually inverse.
    for k in range(q):
        out_k = bundle.neighbors_out[:, k]
        in_k = bundle.neighbors_in[:, k]
        assert np.array_equal(np.sort(out_k), np.arange(p)), (p, k)
        assert np.array_equal(np.sort(in_k), np.arange(p)), (p, k)
        assert np.array_equal(in_k[out_k], np.arange(p)), (p, k)
        # Reversed rounds use the same matching with directions flipped.
        assert np.array_equal(bundle.rev_neighbors_out[:, k], in_k), (p, k)

    # --- Engine vs legacy reference constructions, bit-for-bit.
    if p <= LEGACY_FULL_P:
        legacy_ranks = range(p)
    else:
        legacy_ranks = sorted(
            {0, 1, p - 1, *range(0, p, max(1, p // LEGACY_SAMPLE_RANKS))}
        )
    for r in legacy_ranks:
        assert bundle.recv_row(r) == recv_schedule_legacy(p, r, skip), (p, r)
        assert bundle.send_row(r) == send_schedule_from_recv(p, r, skip), (p, r)
        assert bundle.send_row(r) == send_schedule_legacy(p, r, skip), (p, r)

    # --- One cache entry serves the family: reversed tables are views of
    # the forward arrays (no second O(p log p) build), and rooted bundles
    # are row rotations of the root-0 tables.
    assert bundle.rev_recv is bundle.send and bundle.rev_send is bundle.recv
    for root in sorted({0, 1 % p, p - 1}):
        rooted = get_bundle(p, root)
        virt = (np.arange(p) - root) % p
        assert np.array_equal(rooted.recv, recv[virt]), (p, root)
        assert np.array_equal(rooted.send, send[virt]), (p, root)
        assert rooted.rev_recv is rooted.send and rooted.rev_send is rooted.recv


@pytest.mark.parametrize("p", EDGE_PS)
def test_family_conformance_edge_p(p):
    assert_family_conformance(p)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=512))
def test_family_conformance_sampled_p(p):
    assert_family_conformance(p)


# --------------------------------------------- hierarchical conformance
#
# Two-level (nodes x cores) composition over the same cached engine:
# the composed round count must equal the closed form
# hier_rounds(kind, N, C, n_N, n_C) = sum of per-level flat optima
# (doubled for allreduce), and the composed host data plane must be
# payload-bit-exact against a NumPy reference.  Grid includes the
# paper's 36x32 evaluation topology, non-powers-of-two, and the
# degenerate 1 x p / p x 1 meshes (where hier == the flat collective).

# Deterministic mesh shapes: paper topology, non-powers-of-two both
# levels, degenerate rows/columns, tiny edge meshes.
EDGE_MESHES = [(1, 1), (1, 2), (2, 1), (1, 8), (8, 1), (2, 2), (3, 4),
               (5, 3), (7, 2), (4, 8), (36, 32), (1, 36), (36, 1)]


def assert_hier_conformance(nodes, cores, n_inter, n_intra):
    from repro.core.hier import hier_host_plan, hier_rounds
    from repro.core.schedule import num_rounds

    # --- composed closed form: per-level flat optima, allreduce doubled.
    per_level = num_rounds(nodes, n_inter) + num_rounds(cores, n_intra)
    for kind in ("broadcast", "reduce", "allgather"):
        assert hier_rounds(kind, nodes, cores, n_inter, n_intra) == per_level
    assert hier_rounds("allreduce", nodes, cores, n_inter,
                       n_intra) == 2 * per_level
    # degenerate meshes collapse onto the flat single-level count
    if nodes == 1:
        assert per_level == num_rounds(cores, n_intra)
    if cores == 1:
        assert per_level == num_rounds(nodes, n_inter)

    # --- payload bit-exactness of the composed data plane vs NumPy.
    m = n_inter * n_intra
    rng = np.random.default_rng(nodes * 1000 + cores)
    root = int(rng.integers(0, nodes * cores))
    vals = rng.integers(-10**6, 10**6, size=m).astype(np.int64)
    got = hier_host_plan("broadcast", nodes, cores, n_inter, n_intra,
                         root=root).run(vals)
    assert got.shape == (nodes, cores, m)
    assert (got == vals[None, None]).all(), (nodes, cores, root)

    contrib = rng.integers(-10**6, 10**6,
                           size=(nodes, cores, m)).astype(np.int64)
    red = hier_host_plan("reduce", nodes, cores, n_inter, n_intra,
                         root=root, op="sum").run(contrib)
    np.testing.assert_array_equal(
        red, contrib.reshape(nodes * cores, m).sum(axis=0))

    ar = hier_host_plan("allreduce", nodes, cores, n_inter, n_intra,
                        root=root, op="max").run(contrib)
    expect = contrib.reshape(nodes * cores, m).max(axis=0)
    assert (ar == expect[None, None]).all(), (nodes, cores, root)

    e = 3
    shards = rng.integers(-10**6, 10**6,
                          size=(nodes, cores, e)).astype(np.int64)
    ag = hier_host_plan("allgather", nodes, cores, n_inter,
                        n_intra).run(shards)
    np.testing.assert_array_equal(ag, shards.reshape(nodes * cores, e))


@pytest.mark.parametrize("mesh", EDGE_MESHES,
                         ids=lambda m: f"{m[0]}x{m[1]}")
def test_hier_conformance_edge_meshes(mesh):
    nodes, cores = mesh
    assert_hier_conformance(nodes, cores, n_inter=2, n_intra=3)


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=1, max_value=36),
       st.integers(min_value=1, max_value=32),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=4))
def test_hier_conformance_sampled_meshes(nodes, cores, n_inter, n_intra):
    assert_hier_conformance(nodes, cores, n_inter, n_intra)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=20),
       st.integers(min_value=1, max_value=20))
def test_hier_simulator_round_counts_match_closed_form(nodes, cores):
    """The message-passing hier simulations complete in exactly the
    composed optimum, with the per-level split equal to the flat
    per-level optima."""
    from repro.core import (
        simulate_hier_allreduce,
        simulate_hier_broadcast,
        simulate_hier_reduce,
    )
    from repro.core.schedule import num_rounds

    n_inter, n_intra = 2, 2
    root = (nodes * cores) // 2
    b = simulate_hier_broadcast(nodes, cores, n_inter, n_intra, root=root)
    assert b.rounds == b.optimal_rounds
    assert b.rounds_inter == num_rounds(nodes, n_inter)
    assert b.rounds_intra == num_rounds(cores, n_intra)
    r = simulate_hier_reduce(nodes, cores, n_inter, n_intra, root=root)
    assert r.rounds == r.optimal_rounds == b.rounds
    a = simulate_hier_allreduce(nodes, cores, n_inter, n_intra)
    assert a.rounds == a.optimal_rounds == 2 * b.rounds


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=512), st.integers(min_value=1, max_value=9))
def test_reversed_per_round_tables_match_plan(p, n):
    """The vectorized per-round reversed tables equal the per-entry
    composition of reversed_round_plan with the swapped base tables."""
    bundle = get_bundle(p)
    fwd, acc, ks = bundle.reversed_per_round_tables(n)
    plan = bundle.reversed_round_plan(n)
    assert plan == list(reversed(bundle.round_plan(n)))
    assert fwd.shape == acc.shape == (len(plan), p)
    for t, (k, off) in enumerate(plan):
        assert ks[t] == k
        for r in range(p):
            assert fwd[t, r] == int(bundle.rev_send[r][k]) + off
            assert acc[t, r] == int(bundle.rev_recv[r][k]) + off
        # Reversed Condition 2 per effective entry: what r forwards is
        # exactly what its reversed to-processor accumulates.
        for r in range(p):
            f = (r - bundle.skips[k]) % p
            assert fwd[t, r] == acc[t, f]
