"""Adversarial schedule-conformance tests: the verifier must REJECT.

The 1200+-test suite exercises `verify_bundle` / the Condition 3/4
checkers on *valid* schedules only, which would also pass if the
checkers were vacuous (always-True).  This file certifies the negative
direction: targeted mutations of cached recv/send schedules -- swapping
a round, duplicating a block, breaking the Proposition 4 gather
identity, corrupting a reversed table -- and asserts every single one
is rejected with an AssertionError (or a False from the per-processor
predicate).  Each parametrized case first re-verifies the unmutated
schedule, so a rejection can only come from the mutation itself.

Mutations are applied to *copies* of the engine's cached tables (the
originals are immutable, shared process-wide), both through the
low-level ``verify_schedules`` / ``verify_reversed_schedules`` entry
points and end-to-end through ``verify_bundle`` on a doctored
ScheduleBundle.
"""

import numpy as np
import pytest

from repro.core.engine import ScheduleBundle, get_bundle
from repro.core.schedule import baseblock
from repro.core.verify import (
    check_condition_3,
    check_condition_4,
    check_reversed_condition_3,
    check_reversed_condition_4,
    verify_bundle,
    verify_reversed_schedules,
    verify_schedules,
)

# Axis sizes with q >= 2 (mutations below need at least two rounds) and
# boundary coverage: powers of two, +-1 neighbours, the paper's p=11/36.
PS = [4, 5, 7, 8, 11, 16, 17, 31, 32, 36, 63, 64]


def _rows(bundle):
    """Writable (recv, send) row lists in virtual numbering (root 0)."""
    return ([bundle.recv_row(r) for r in range(bundle.p)],
            [bundle.send_row(r) for r in range(bundle.p)])


def _nonroot_rank_with_distinct_cols(rows, q):
    """(r, k, k') with r != 0 and rows[r][k] != rows[r][k']."""
    for r in range(1, len(rows)):
        for k in range(q):
            for kk in range(k + 1, q):
                if rows[r][k] != rows[r][kk]:
                    return r, k, kk
    raise AssertionError("no distinct pair found (q too small?)")


# ------------------------------------------------ forward-table mutations


@pytest.mark.parametrize("p", PS)
def test_swap_a_round_is_rejected(p):
    """Swapping two rounds of one rank's recv schedule keeps the block
    *set* (Condition 3 still holds) but desynchronizes the rank from
    its neighbours -- Conditions 1/2/4 must catch it."""
    bundle = get_bundle(p)
    recv, send = _rows(bundle)
    verify_schedules(p, recv, send)  # positive control
    r, k, kk = _nonroot_rank_with_distinct_cols(recv, bundle.q)
    recv[r][k], recv[r][kk] = recv[r][kk], recv[r][k]
    with pytest.raises(AssertionError):
        verify_schedules(p, recv, send)


@pytest.mark.parametrize("p", PS)
def test_duplicate_a_block_is_rejected(p):
    """Overwriting one recv entry with another duplicates a block, so
    the rank never receives the overwritten one -- Condition 3's
    distinctness must catch it."""
    bundle = get_bundle(p)
    recv, send = _rows(bundle)
    verify_schedules(p, recv, send)
    r, k, kk = _nonroot_rank_with_distinct_cols(recv, bundle.q)
    recv[r][k] = recv[r][kk]
    b = baseblock(r, bundle.skips, bundle.q)
    assert not check_condition_3(recv[r], b, bundle.q)
    with pytest.raises(AssertionError):
        verify_schedules(p, recv, send)


@pytest.mark.parametrize("p", PS)
def test_broken_gather_identity_is_rejected(p):
    """send[r][k] must equal recv[(r + skip[k]) % p][k] (Prop. 4 /
    Condition 2); nudging one send entry off that value must fail."""
    bundle = get_bundle(p)
    recv, send = _rows(bundle)
    verify_schedules(p, recv, send)
    q, skip = bundle.q, bundle.skips
    r, k = 1, 0
    t = (r + skip[k]) % p
    assert send[r][k] == recv[t][k]  # the identity we are about to break
    send[r][k] = recv[t][k] + 1
    with pytest.raises(AssertionError):
        verify_schedules(p, recv, send)


@pytest.mark.parametrize("p", PS)
def test_corrupted_root_send_row_is_rejected(p):
    """The root must send blocks 0..q-1 in order; any permutation of
    that row is rejected."""
    bundle = get_bundle(p)
    recv, send = _rows(bundle)
    send[0][0], send[0][-1] = send[0][-1], send[0][0]
    with pytest.raises(AssertionError):
        verify_schedules(p, recv, send)


@pytest.mark.parametrize("p", PS)
def test_condition4_rejects_unreceived_send(p):
    """A rank sending a block before receiving it (and that is not its
    phase-carried baseblock) violates Condition 4."""
    bundle = get_bundle(p)
    q, skip = bundle.q, bundle.skips
    recv, send = _rows(bundle)
    r, k, kk = _nonroot_rank_with_distinct_cols(recv, q)
    b = baseblock(r, skip, q)
    # Make round 1 send a block that is neither b-q (the phase-carried
    # baseblock) nor anything received in round 0.
    poison = max(max(recv[r]), max(send[r]), b) + 1
    sent = list(send[r])
    sent[min(1, q - 1)] = poison
    assert not check_condition_4(recv[r], sent, b, q)
    # And the full verifier rejects the poisoned table end-to-end.
    send[r] = sent
    with pytest.raises(AssertionError):
        verify_schedules(p, recv, send)


# ----------------------------------------------- reversed-table mutations


@pytest.mark.parametrize("p", PS)
def test_reversed_duplicate_forward_is_rejected(p):
    """Duplicating a partial in a reversed send row means some block is
    never forwarded -- a non-root would keep a contribution forever.
    Reversed Condition 3 must catch it."""
    bundle = get_bundle(p)
    recv, send = _rows(bundle)
    # Reversed roles: recv_rev == forward send, send_rev == forward recv.
    verify_reversed_schedules(p, recv_rev=send, send_rev=recv)
    r, k, kk = _nonroot_rank_with_distinct_cols(recv, bundle.q)
    b = baseblock(r, bundle.skips, bundle.q)
    recv[r][k] = recv[r][kk]
    assert not check_reversed_condition_3(recv[r], b, bundle.q)
    with pytest.raises(AssertionError):
        verify_reversed_schedules(p, recv_rev=send, send_rev=recv)


@pytest.mark.parametrize("p", PS)
def test_reversed_root_accumulation_row_is_rejected(p):
    """The root's reversed accumulation row is the forward root send row
    0..q-1; corrupting it must be rejected."""
    bundle = get_bundle(p)
    recv, send = _rows(bundle)
    send[0][0] = send[0][0] + 1
    with pytest.raises(AssertionError):
        verify_reversed_schedules(p, recv_rev=send, send_rev=recv)


@pytest.mark.parametrize("p", PS)
def test_reversed_condition4_rejects_stalled_partial(p):
    """A partial accumulated in reversed round k must be forwarded in a
    reversed-later round (column j < k) or be the phase-carried
    baseblock; an accumulation with neither stalls on the rank."""
    bundle = get_bundle(p)
    q = bundle.q
    recv, send = _rows(bundle)
    r, _, _ = _nonroot_rank_with_distinct_cols(recv, q)
    b = baseblock(r, bundle.skips, q)
    rev_recv = list(send[r])   # the rank's reversed accumulation row
    rev_send = list(recv[r])   # the rank's reversed forward row
    assert check_reversed_condition_4(rev_recv, rev_send, b, q)
    poison = max(max(rev_recv), max(rev_send), b) + 1
    stalled = list(rev_recv)
    stalled[q - 1] = poison    # accumulated last, never forwarded
    assert not check_reversed_condition_4(stalled, rev_send, b, q)


# ------------------------------------------------- end-to-end via bundles


def _doctored_bundle(bundle, recv=None, send=None) -> ScheduleBundle:
    """A ScheduleBundle with corrupted table copies (the cached arrays
    are immutable and shared -- never mutate them in place)."""
    return ScheduleBundle(
        p=bundle.p, root=bundle.root, q=bundle.q, skips=bundle.skips,
        recv=np.array(recv if recv is not None else bundle.recv),
        send=np.array(send if send is not None else bundle.send),
    )


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("root", [0, 1])
def test_verify_bundle_rejects_corrupt_recv(p, root):
    bundle = get_bundle(p, root)
    verify_bundle(bundle)  # positive control
    recv = np.array(bundle.recv)
    r = (1 + root) % p
    k, kk = 0, bundle.q - 1
    if recv[r][k] == recv[r][kk]:  # ensure a real change
        recv[r][k] = recv[r][kk] + 1
    else:
        recv[r][k], recv[r][kk] = recv[r][kk], recv[r][k]
    with pytest.raises(AssertionError):
        verify_bundle(_doctored_bundle(bundle, recv=recv))


@pytest.mark.parametrize("p", PS)
def test_verify_bundle_rejects_corrupt_send(p):
    bundle = get_bundle(p)
    send = np.array(bundle.send)
    send[2 % p][0] += 1
    with pytest.raises(AssertionError):
        verify_bundle(_doctored_bundle(bundle, send=send))


@pytest.mark.parametrize("p", PS)
def test_verify_bundle_rejects_swapped_tables(p):
    """Swapping the recv and send tables wholesale (a plausible wiring
    bug: the reversed aliases point the wrong way) must be rejected."""
    bundle = get_bundle(p)
    with pytest.raises(AssertionError):
        verify_bundle(_doctored_bundle(bundle, recv=bundle.send,
                                       send=bundle.recv))


def test_every_entry_mutation_rejected_exhaustively():
    """For a small p, EVERY single-entry +1 nudge of either table is
    rejected -- there is no entry the verifier does not constrain."""
    p = 11
    bundle = get_bundle(p)
    for table in ("recv", "send"):
        for r in range(p):
            for k in range(bundle.q):
                recv = np.array(bundle.recv)
                send = np.array(bundle.send)
                (recv if table == "recv" else send)[r][k] += 1
                with pytest.raises(AssertionError):
                    verify_bundle(_doctored_bundle(bundle, recv=recv,
                                                   send=send))


def test_positive_control_family():
    """The unmutated engine tables pass both directions for every p used
    above (so the rejections cannot come from a broken fixture)."""
    for p in PS:
        verify_bundle(get_bundle(p))
