"""repro.analysis: positive sweeps + adversarial corruption injection.

The analyzer is only worth its CI minutes if it (a) passes clean on
every real artifact and (b) REJECTS corrupted ones -- a vacuous checker
passes (a) trivially.  Mirroring tests/test_verify_negative.py, every
negative case here first audits the *unmutated* artifact clean, then
injects one corruption into a COPY (cached tables are immutable and
shared process-wide; nothing here may touch the originals) and asserts
the matching pass reports the matching check id.

Corruption classes covered (each keyed to its Finding.check):
  plan pass   -- write-once, raw-send, exchange, slot-range, ks-sequence,
                 rotation, round-count, root-pin, lost-partial,
                 mutable-table, bundle-consistency, phase-layout
  kernel pass -- ww-overlap, raw-alias, alias-map, dtype-widening
  cache pass  -- mutable-cache-entry
  lint pass   -- frozen-plan, mutable-default, host-plane-jax, api-doc
"""

import dataclasses
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (
    AnalysisError,
    Report,
    audit_cache,
    audit_hier_kind,
    audit_kind,
    audit_phase,
    audit_plan,
    audit_statics,
    statics_for_kind,
)
from repro.analysis.lint import lint_api_docs, lint_repo, lint_source
from repro.analysis.planaudit import HIER_PLAN_KINDS, PLAN_KINDS
from repro.core.engine import get_bundle

from conftest import run_worker

ROOT = Path(__file__).resolve().parents[1]


def _thaw(ps, which):
    """A copy of phase static ``ps`` with slot table ``which`` writable
    (refrozen copies of the rest): mutate, refreeze, rebuild."""
    slots = []
    for i, tab in enumerate(ps.slots):
        c = tab.copy()
        if i != which:
            c.setflags(write=False)
        slots.append(c)
    return dataclasses.replace(ps, slots=tuple(slots)), slots


def _refrozen(ps, slots):
    for s in slots:
        s.setflags(write=False)
    return ps


def _bcast(p=5, n=4, root=0):
    (ps,) = statics_for_kind("broadcast", p, n, root)
    assert audit_statics((ps,)).ok, "clean broadcast static must audit ok"
    return ps


def _reduce(p=5, n=4, root=0):
    (ps,) = statics_for_kind("reduce", p, n, root)
    assert audit_statics((ps,)).ok, "clean reduce static must audit ok"
    return ps


# ------------------------------------------------------- positive sweeps


@pytest.mark.parametrize("kind", PLAN_KINDS)
@pytest.mark.parametrize("p", [2, 3, 4, 7, 8, 16, 17, 36, 64])
def test_audit_kind_clean(kind, p):
    rep = audit_kind(kind, p, n=4, root=p - 1)
    assert rep.ok, rep.summary()
    assert rep.checked > 0
    rep.raise_if_failed()  # must not raise when clean


@pytest.mark.parametrize("kind", HIER_PLAN_KINDS)
@pytest.mark.parametrize("mesh", [(2, 2), (2, 4), (6, 4), (36, 32)])
def test_audit_hier_kind_clean(kind, mesh):
    nodes, cores = mesh
    rep = audit_hier_kind(kind, nodes, cores, n_inter=4, n_intra=4)
    assert rep.ok, rep.summary()
    assert rep.checked > 0


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("kind",
                         ["broadcast", "allgather", "reduce",
                          "quantized_allreduce"])
def test_audit_host_plan_clean(backend, kind):
    from repro.core.comm import host_plan

    plan = host_plan(kind, 5, n=4, backend=backend)
    rep = audit_plan(plan)
    assert rep.ok, rep.summary()
    assert rep.checked > 1  # the plan itself plus >= 1 phase


@pytest.mark.parametrize("kind", HIER_PLAN_KINDS)
def test_audit_hier_host_plan_clean(kind):
    from repro.core.hier import hier_host_plan

    plan = hier_host_plan(kind, 2, 4, 2, 3)
    rep = audit_plan(plan)
    assert rep.ok, rep.summary()


def test_cache_audit_clean():
    get_bundle(7, 0)  # ensure the cache is non-trivial
    rep = audit_cache()
    assert rep.ok, rep.summary()
    assert rep.checked > 0


def test_lint_repo_clean():
    rep = lint_repo(ROOT)
    assert rep.ok, rep.summary()
    assert rep.checked > 30  # the whole src/repro tree was walked


def test_report_aggregation():
    a = audit_kind("broadcast", 5, 4)
    b = audit_kind("reduce", 5, 4)
    both = a + b
    assert both.checked == a.checked + b.checked
    assert both.raise_if_failed() is both  # clean -> returns self


# ------------------------------------------- plan-pass corruption classes


def test_duplicate_recv_slot_rejected():  # class 1: write-once
    ps = _bcast()
    bad, slots = _thaw(ps, 0)
    recv = slots[0]
    # rank 1's real receives are distinct; alias round t2 onto t1
    col = recv[:, 1]
    real_rounds = np.flatnonzero(col < ps.n - 1)
    assert len(real_rounds) >= 2
    recv[real_rounds[1], 1] = recv[real_rounds[0], 1]
    rep = audit_statics((_refrozen(bad, slots),))
    assert rep.has("write-once"), rep.summary()
    with pytest.raises(AnalysisError):
        rep.raise_if_failed()


def test_out_of_range_slot_rejected():  # class 2: slot-range
    ps = _bcast()
    bad, slots = _thaw(ps, 0)
    slots[0][0, 0] = ps.nslots + 3
    rep = audit_statics((_refrozen(bad, slots),))
    assert rep.has("slot-range"), rep.summary()


def test_round_count_drift_rejected():  # class 3: round-count
    ps = _bcast()
    sliced = tuple(t[:-1].copy() for t in ps.slots)
    for t in sliced:
        t.setflags(write=False)
    bad = dataclasses.replace(ps, slots=sliced, ks=ps.ks[:-1],
                              shifts=ps.shifts[:-1])
    rep = audit_statics((bad,))
    assert rep.has("round-count"), rep.summary()


def test_wrong_ks_column_rejected():  # class 4: ks-sequence
    ps = _bcast(p=8)
    bad = dataclasses.replace(ps, ks=np.ascontiguousarray(ps.ks[::-1]))
    rep = audit_statics((bad,))
    assert rep.has("ks-sequence"), rep.summary()


def test_wrong_rotation_rejected():  # class 5: rotation
    ps = _bcast()
    shifts = list(ps.shifts)
    shifts[0] = (shifts[0] + 1) % ps.p
    bad = dataclasses.replace(ps, shifts=tuple(shifts))
    rep = audit_statics((bad,))
    assert rep.has("rotation"), rep.summary()


def test_exchange_inconsistency_rejected():  # class 6: exchange
    ps = _bcast()
    bad, slots = _thaw(ps, 1)
    send = slots[1]
    # divert one real send to a different (valid-range) slot
    t, r = np.argwhere(send < ps.n - 1)[0]
    send[t, r] = (send[t, r] + 1) % (ps.n - 1)
    rep = audit_statics((_refrozen(bad, slots),))
    assert rep.has("exchange"), rep.summary()


def test_send_before_receive_rejected():  # class 7: raw-send (RAW order)
    ps = _bcast()
    bad, slots = _thaw(ps, 1)
    send = slots[1]
    r = (ps.root + 1) % ps.p
    send[0, r] = 0  # a real slot, but round 0 precedes any receive
    rep = audit_statics((_refrozen(bad, slots),))
    assert rep.has("raw-send"), rep.summary()


def test_unpinned_root_fwd_rejected():  # class 8: root-pin
    ps = _reduce()
    bad, slots = _thaw(ps, 0)
    slots[0][0, ps.root] = 0  # leak a live partial from the root
    rep = audit_statics((_refrozen(bad, slots),))
    assert rep.has("root-pin"), rep.summary()


def test_lost_partial_rejected():  # class 9: lost-partial
    ps = _reduce()
    bad, slots = _thaw(ps, 1)
    acc = slots[1]
    r = (ps.root + 1) % ps.p
    acc[-1, r] = 0  # accumulate a real partial with no later forward
    rep = audit_statics((_refrozen(bad, slots),))
    assert rep.has("lost-partial"), rep.summary()


def test_writable_table_rejected():  # class 10: mutable-table
    ps = _bcast()
    thawed = tuple(t.copy() for t in ps.slots)  # copies stay writable
    bad = dataclasses.replace(ps, slots=thawed)
    rep = audit_statics((bad,))
    assert rep.has("mutable-table"), rep.summary()
    assert not rep.has("bundle-consistency"), \
        "values were unchanged; only mutability may fire"


def test_foreign_tables_rejected():  # class 11: bundle-consistency
    ps = _bcast(p=5)
    other = _bcast(p=5, root=2)  # right shapes, wrong root's tables
    bad = dataclasses.replace(ps, slots=other.slots)
    rep = audit_statics((bad,))
    assert rep.has("bundle-consistency"), rep.summary()


class _FakeFlatPlan:
    kind = "allreduce"
    p = 5
    root = 0
    n_blocks = 4
    backend = "jnp"
    rounds = 99  # closed form is 2*(n-1) + 2*ceil(log2 p) = 12

    @property
    def statics(self):
        # reduce phase missing: broadcast only, and twice
        (b,) = statics_for_kind("broadcast", 5, 4, 0)
        return (b, b)


def test_fake_plan_layout_rejected():  # class 12: phase-layout+round-count
    rep = audit_plan(_FakeFlatPlan())
    assert rep.has("round-count"), rep.summary()
    assert rep.has("phase-layout"), rep.summary()


def test_plan_without_statics_rejected():
    class Bare:
        pass

    rep = audit_plan(Bare())
    assert rep.has("no-statics")


# ----------------------------------------- kernel-pass corruption classes


def _pack_spec(R=4, nslots=5, bs=8):
    from repro.kernels import block_pack as bp

    spec = bp.kernel_audit_spec("block_pack", R=R, nslots=nslots, bs=bs)
    from repro.analysis.kernelaudit import replay_kernel

    idx = np.arange(R, dtype=np.int32) % nslots
    assert not replay_kernel(spec, (idx,)), "clean spec must replay clean"
    return bp, spec, idx


def test_overlapping_output_blocks_rejected():  # class 13: ww-overlap
    from repro.analysis.kernelaudit import replay_kernel

    bp, spec, idx = _pack_spec()
    evil_out = dataclasses.replace(
        spec.outputs[0], index_map=lambda r, i: (0, 0))  # every r -> row 0
    bad = dataclasses.replace(spec, outputs=(evil_out,))
    findings = replay_kernel(bad, (idx,))
    assert any(f.check == "ww-overlap" for f in findings), findings


def test_alias_read_back_rejected():  # class 14: raw-alias
    from repro.analysis.kernelaudit import replay_kernel

    bp = pytest.importorskip("repro.kernels.block_pack")
    R, nslots, bs = 4, 5, 8
    spec = bp.kernel_audit_spec("block_unpack", R=R, nslots=nslots, bs=bs)
    idx = np.zeros(R, dtype=np.int32)  # every row writes slot 0...
    # ...and the aliased input becomes LIVE and reads the previous row's
    # written block: the exact interpret/compiled divergence hazard.
    live_alias = dataclasses.replace(
        spec.inputs[1], live=None,
        index_map=lambda r, i: (max(r - 1, 0), i[max(r - 1, 0)], 0))
    bad = dataclasses.replace(spec, inputs=(spec.inputs[0], live_alias))
    findings = replay_kernel(bad, (idx,))
    assert any(f.check == "raw-alias" for f in findings), findings


def test_alias_map_mismatch_rejected():  # class 15: alias-map
    from repro.analysis.kernelaudit import replay_kernel

    bp, spec, idx = _pack_spec()
    from repro.kernels.block_pack import kernel_audit_spec

    spec = kernel_audit_spec("block_unpack", R=4, nslots=5, bs=8)
    skewed = dataclasses.replace(
        spec.inputs[1], index_map=lambda r, i: (r, (i[r] + 1) % 5, 0))
    bad = dataclasses.replace(spec, inputs=(spec.inputs[0], skewed))
    findings = replay_kernel(bad, (np.arange(4, dtype=np.int32),))
    assert any(f.check == "alias-map" for f in findings), findings


def test_dtype_drift_rejected():  # class 16: dtype-widening
    from repro.analysis.kernelaudit import audit_kernel_trace
    from repro.kernels.block_pack import kernel_audit_spec

    spec = kernel_audit_spec("block_acc_shuffle", R=3, nslots=4, bs=8)
    lying = dataclasses.replace(
        spec, out_dtypes=lambda dt: (np.dtype(np.float64), dt))
    findings = audit_kernel_trace("block_acc_shuffle", R=3, nslots=4,
                                  bs=8, spec=lying)
    assert any(f.check == "dtype-widening" for f in findings), findings


def test_kernel_registry_traces_clean():
    from repro.analysis.kernelaudit import audit_kernels

    rep = audit_kernels(ps=(3, 5), ns=(4,))
    assert rep.ok, rep.summary()
    assert rep.checked > 0


# ------------------------------------------ cache-pass corruption class


def test_writable_cache_entry_rejected():  # class 17: mutable-cache-entry
    frozen = np.zeros(3)
    frozen.setflags(write=False)
    fake_cache = {
        ("slots/test", 5, 0, 4): (frozen, np.zeros(3)),  # 2nd is writable
    }
    rep = audit_cache(fake_cache)
    assert rep.has("mutable-cache-entry"), rep.summary()
    assert rep.checked == 1


# ------------------------------------------- lint-pass corruption classes


def test_unfrozen_plan_dataclass_rejected():  # class 18: frozen-plan
    src = ("from dataclasses import dataclass\n"
           "@dataclass\n"
           "class EvilPlan:\n"
           "    x: int = 0\n")
    findings = lint_source(src, "evil.py")
    assert any(f.check == "frozen-plan" for f in findings), findings
    ok = src.replace("@dataclass", "@dataclass(frozen=True)")
    assert not lint_source(ok, "ok.py")


def test_mutable_default_rejected():  # class 19: mutable-default
    findings = lint_source("def f(xs=[]):\n    return xs\n", "evil.py")
    assert any(f.check == "mutable-default" for f in findings), findings
    findings = lint_source("def g(*, m=dict()):\n    return m\n", "evil.py")
    assert any(f.check == "mutable-default" for f in findings), findings
    assert not lint_source("def h(x=(), y=None):\n    return x\n", "ok.py")


def test_host_plane_jax_import_rejected():  # class 20: host-plane-jax
    findings = lint_source("import jax.numpy as jnp\n", "core/x.py",
                           host_plane=True)
    assert any(f.check == "host-plane-jax" for f in findings), findings
    findings = lint_source("from jax import numpy\n", "core/x.py",
                           host_plane=True)
    assert any(f.check == "host-plane-jax" for f in findings), findings
    # lazy function-local imports are the sanctioned escape hatch
    assert not lint_source("def f():\n    import jax\n    return jax\n",
                           "core/x.py", host_plane=True)
    # and non-host-plane modules may import jax freely
    assert not lint_source("import jax\n", "models/x.py", host_plane=False)


def test_kernel_interpret_default_rejected():  # class 22: kernel-interpret
    """Public kernel entry points must default interpret=None (platform
    auto-detect via resolve_interpret): a baked-in True never compiles
    the kernel on a real accelerator, a baked-in False breaks every
    host-only environment."""
    for baked in ("True", "False"):
        src = (f"def schedule_op(x, *, interpret={baked}):\n"
               f"    return x\n")
        findings = lint_source(src, "kernels/x.py", kernel_plane=True)
        assert any(f.check == "kernel-interpret" for f in findings), findings
    # interpret=None is the sanctioned default
    assert not lint_source(
        "def schedule_op(x, *, interpret=None):\n    return x\n",
        "kernels/x.py", kernel_plane=True)
    # private helpers may thread a resolved bool
    assert not lint_source(
        "def _impl(x, interpret=True):\n    return x\n",
        "kernels/x.py", kernel_plane=True)
    # non-kernel-plane modules are out of scope for this rule
    assert not lint_source(
        "def schedule_op(x, *, interpret=True):\n    return x\n",
        "train/x.py", kernel_plane=False)


def test_undocumented_symbol_rejected(tmp_path):  # class 21: api-doc
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "docs").mkdir()
    (tmp_path / "src/repro/core/__init__.py").write_text(
        '__all__ = ["documented_fn", "ghost_fn"]\n')
    (tmp_path / "docs/api.md").write_text("# API\n`documented_fn` only\n")
    findings = lint_api_docs(tmp_path)
    assert any(f.check == "api-doc" and "ghost_fn" in f.message
               for f in findings), findings


# ------------------------------------------------------ device coverage


@pytest.mark.multidevice
@pytest.mark.parametrize("p", [2, 4])
def test_device_plan_audit(p):
    run_worker("analysis", p, "jnp", 2)


@pytest.mark.multidevice
def test_device_plan_audit_pallas():
    run_worker("analysis", 4, "pallas", 2)


# --------------------------------------------------------------- the CLI


def test_cli_plans_lint_cache(tmp_path, capsys):
    from repro.analysis.__main__ import main

    bench = tmp_path / "bench.json"
    assert main(["--plans", "--lint", "--cache",
                 "--bench", str(bench)]) == 0
    out = capsys.readouterr().out
    assert "OK:" in out and bench.exists()
    import json

    payload = json.loads(bench.read_text())
    assert payload["total"]["findings"] == 0
    assert payload["passes"]["plans"]["checked"] > 0
