"""End-to-end simulation tests for the collective family: broadcast /
all-broadcast (forward schedules) and reduction / all-reduction (reversed
schedules), payload-checked delivery in exactly the optimal round counts.

The broadcast / reduce / allreduce grids are parametrized over the
round-step data-plane backend: ``"jnp"`` / ``"pallas"`` run the
message-passing reference AND the real data plane (Pallas in interpret
mode on CPU), asserting bit-exact agreement -- the certification
required by docs/kernels.md.  (A ``backend=None`` lane would be a
strict subset of the ``"jnp"`` run, so it is deliberately absent.)"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.schedule import ceil_log2, num_rounds
from repro.core.simulator import (
    simulate_allbroadcast,
    simulate_allgather,
    simulate_allreduce,
    simulate_broadcast,
    simulate_reduce,
)

# The reversed-family acceptance grid: every (p, n, root) combination.
FAMILY_PS = [1, 2, 3, 5, 8, 11, 36, 64]
FAMILY_NS = [1, 2, 4, 7]
BACKENDS = ["jnp", "pallas"]


def _roots(p):
    return sorted({0, 1 % p, p - 1})


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 7, 8, 16, 17, 31, 33, 100])
@pytest.mark.parametrize("n", [1, 2, 3, 7, 11])
def test_broadcast_delivers_optimal_rounds(p, n, backend):
    res = simulate_broadcast(p, n, backend=backend)
    assert res.rounds == res.optimal_rounds


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", [5, 17, 33])
@pytest.mark.parametrize("root", [0, 1, 3, 4])
def test_broadcast_nonzero_root(p, root, backend):
    res = simulate_broadcast(p, 6, root=root, backend=backend)
    assert res.rounds == res.optimal_rounds


@pytest.mark.parametrize("p", [1, 2, 3, 5, 8, 13, 17, 33])
@pytest.mark.parametrize("n", [1, 2, 5, 9])
def test_allgather_delivers_optimal_rounds(p, n):
    res = simulate_allgather(p, n)
    assert res.rounds == res.optimal_rounds


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=150), st.integers(min_value=1, max_value=16))
def test_broadcast_hypothesis(p, n):
    simulate_broadcast(p, n)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=40), st.integers(min_value=1, max_value=8))
def test_allgather_hypothesis(p, n):
    simulate_allgather(p, n)


def test_broadcast_volume_is_optimal():
    # Every non-root receives each block exactly once: (p-1)*n block moves.
    for p, n in [(8, 4), (17, 5), (33, 3)]:
        res = simulate_broadcast(p, n)
        assert res.blocks_moved == (p - 1) * n


# ------------------------------------------- reversed-schedule family


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", FAMILY_PS)
@pytest.mark.parametrize("n", FAMILY_NS)
def test_reduce_round_optimal_and_bitexact(p, n, backend):
    """Reduction completes in exactly n-1+q rounds for every root and the
    result matches the NumPy reference reduction bit-exactly (the jnp and
    pallas data planes are certified against the same reference)."""
    rng = np.random.default_rng(p * 100 + n)
    for root in _roots(p):
        vals = rng.integers(-(1 << 31), 1 << 31, size=(p, n)).astype(np.int64)
        res = simulate_reduce(p, n, root=root, values=vals, backend=backend)
        assert res.rounds == res.optimal_rounds == num_rounds(p, n)
        got = np.array([res.buffers[root][j] for j in range(n)])
        assert np.array_equal(got, vals.sum(axis=0))

        fvals = rng.normal(size=(p, n))
        resm = simulate_reduce(p, n, root=root, op="max", values=fvals,
                               backend=backend)
        assert resm.rounds == resm.optimal_rounds == num_rounds(p, n)
        gotm = np.array([resm.buffers[root][j] for j in range(n)])
        assert np.array_equal(gotm, fvals.max(axis=0))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", FAMILY_PS)
@pytest.mark.parametrize("n", FAMILY_NS)
def test_allreduce_round_optimal_and_bitexact(p, n, backend):
    """All-reduction completes in exactly 2(n-1)+2*ceil(log2 p) rounds for
    every root and delivers the bit-exact reduction to EVERY rank; the
    jnp/pallas data planes of both phases are certified on the grid."""
    rng = np.random.default_rng(p * 1000 + n)
    for root in _roots(p):
        vals = rng.integers(-(1 << 31), 1 << 31, size=(p, n)).astype(np.int64)
        res = simulate_allreduce(p, n, root=root, values=vals, backend=backend)
        predicted = 0 if p == 1 else 2 * (n - 1) + 2 * ceil_log2(p)
        assert res.rounds == res.optimal_rounds == predicted
        expect = vals.sum(axis=0)
        for r in range(p):
            got = np.array([res.buffers[r][j] for j in range(n)])
            assert np.array_equal(got, expect), (p, n, root, r)

        fvals = rng.normal(size=(p, n))
        resm = simulate_allreduce(p, n, root=root, op="max", values=fvals,
                                  backend=backend)
        assert resm.rounds == resm.optimal_rounds == predicted
        expectm = fvals.max(axis=0)
        for r in range(p):
            gotm = np.array([resm.buffers[r][j] for j in range(n)])
            assert np.array_equal(gotm, expectm), (p, n, root, r)


@pytest.mark.parametrize("p", FAMILY_PS)
@pytest.mark.parametrize("n", FAMILY_NS)
def test_allbroadcast_round_optimal(p, n):
    res = simulate_allbroadcast(p, n)
    assert res.rounds == res.optimal_rounds == num_rounds(p, n)


def test_reduce_volume_matches_broadcast():
    # Time reversal preserves the edge multiset: the reduction moves real
    # partials over the same count of edges or fewer (idle capped rounds
    # forward identity-only partials, which still count as a block move).
    for p, n in [(8, 4), (17, 5), (33, 3)]:
        fwd = simulate_broadcast(p, n)
        rev = simulate_reduce(p, n)
        assert rev.rounds == fwd.rounds
        assert rev.blocks_moved >= (p - 1) * n


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=100), st.integers(min_value=1, max_value=13))
def test_reduce_hypothesis(p, n):
    simulate_reduce(p, n)


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=40), st.integers(min_value=1, max_value=8))
def test_allreduce_hypothesis(p, n):
    simulate_allreduce(p, n)
