"""End-to-end simulation tests for Algorithm 1 (broadcast) and Algorithm 2
(all-to-all broadcast): payload-checked delivery in exactly n-1+q rounds."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.simulator import simulate_allgather, simulate_broadcast


@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 7, 8, 16, 17, 31, 33, 100])
@pytest.mark.parametrize("n", [1, 2, 3, 7, 11])
def test_broadcast_delivers_optimal_rounds(p, n):
    res = simulate_broadcast(p, n)
    assert res.rounds == res.optimal_rounds


@pytest.mark.parametrize("p", [5, 17, 33])
@pytest.mark.parametrize("root", [0, 1, 3, 4])
def test_broadcast_nonzero_root(p, root):
    res = simulate_broadcast(p, 6, root=root)
    assert res.rounds == res.optimal_rounds


@pytest.mark.parametrize("p", [1, 2, 3, 5, 8, 13, 17, 33])
@pytest.mark.parametrize("n", [1, 2, 5, 9])
def test_allgather_delivers_optimal_rounds(p, n):
    res = simulate_allgather(p, n)
    assert res.rounds == res.optimal_rounds


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=150), st.integers(min_value=1, max_value=16))
def test_broadcast_hypothesis(p, n):
    simulate_broadcast(p, n)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=40), st.integers(min_value=1, max_value=8))
def test_allgather_hypothesis(p, n):
    simulate_allgather(p, n)


def test_broadcast_volume_is_optimal():
    # Every non-root receives each block exactly once: (p-1)*n block moves.
    for p, n in [(8, 4), (17, 5), (33, 3)]:
        res = simulate_broadcast(p, n)
        assert res.blocks_moved == (p - 1) * n
