"""Engine tests: ScheduleBundle round-trips, caching, and edge cases.

The engine's batched tables must agree bit-for-bit with the per-rank
O(log p) algorithms (Algorithms 3-9) for every p, every root: the
per-rank functions are the paper-faithful ground truth, the engine is
the production path every consumer actually uses.
"""

import numpy as np
import pytest

from repro.core.engine import (
    ScheduleBundle,
    baseblock_table,
    bundle_cache_clear,
    bundle_cache_info,
    get_bundle,
)
from repro.core.schedule import (
    baseblock,
    ceil_log2,
    compute_skips,
    num_rounds,
    recv_schedule,
    send_schedule,
    virtual_rounds,
)
from repro.core.verify import verify_bundle


# ------------------------------------------------------------- round-trip


@pytest.mark.parametrize("p", list(range(1, 65)))
def test_bundle_round_trips_per_rank_algorithms(p):
    """Acceptance: engine == recv_schedule/send_schedule for p in 1..64
    and roots {0, 1, p-1} (rows relabeled to real ranks)."""
    skip = compute_skips(p)
    for root in sorted({0, 1 % p, p - 1}):
        bundle = get_bundle(p, root)
        assert (bundle.p, bundle.root, bundle.q) == (p, root, ceil_log2(p))
        assert bundle.skips == skip
        assert bundle.recv.shape == bundle.send.shape == (p, bundle.q)
        for r in range(p):
            v = (r - root) % p  # virtual rank of real rank r
            assert bundle.recv_row(r) == recv_schedule(p, v, skip)
            assert bundle.send_row(r) == send_schedule(p, v, skip)


@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 8, 11, 16, 32, 36, 64, 100, 1024])
def test_bundle_satisfies_correctness_conditions(p):
    verify_bundle(get_bundle(p))


@pytest.mark.parametrize("p", [3, 5, 11, 36])
def test_bundle_nonzero_roots_satisfy_conditions(p):
    for root in range(p):
        verify_bundle(get_bundle(p, root))


def test_baseblock_table_matches_scalar():
    for p in [1, 2, 3, 5, 11, 36, 64, 100, 257]:
        q = ceil_log2(p)
        skip = compute_skips(p)
        expect = [baseblock(r, skip, q) for r in range(p)]
        assert baseblock_table(p).tolist() == expect


# ------------------------------------------------------------ edge cases


def test_p1_trivial_bundle():
    b = get_bundle(1)
    assert b.q == 0
    assert b.recv.shape == b.send.shape == (1, 0)
    assert b.rounds(7) == 0
    assert b.round_plan(1) == []
    assert b.baseblocks.tolist() == [0]  # q == 0: the root's baseblock is q


def test_p2_single_round():
    b = get_bundle(2)
    assert b.q == 1
    assert b.recv_row(0) == [-1] and b.recv_row(1) == [0]
    assert b.send_row(0) == [0]
    assert b.rounds(3) == 3


@pytest.mark.parametrize("p", [2, 4, 8, 16, 32, 64])
def test_powers_of_two_baseblock_is_lowest_set_bit(p):
    b = get_bundle(p)
    bb = b.baseblocks
    assert bb[0] == b.q
    for r in range(1, p):
        assert bb[r] == (r & -r).bit_length() - 1


def test_invalid_root_rejected():
    with pytest.raises(ValueError):
        get_bundle(5, 5)
    with pytest.raises(ValueError):
        get_bundle(5, -1)


# --------------------------------------------------------------- caching


def test_cache_hit_identity():
    bundle_cache_clear()
    assert get_bundle(36) is get_bundle(36)
    assert get_bundle(36, 7) is get_bundle(36, 7)
    assert get_bundle(36) is not get_bundle(36, 7)
    info, _ = bundle_cache_info()
    assert info.hits >= 2


def test_rooted_bundles_share_table_computation():
    bundle_cache_clear()
    get_bundle(17, 1)
    get_bundle(17, 5)
    _, tables_info = bundle_cache_info()
    assert tables_info.misses == 1  # root-0 tables computed once, rotated twice


def test_tables_are_immutable():
    b = get_bundle(11)
    with pytest.raises(ValueError):
        b.recv[0, 0] = 99
    with pytest.raises(ValueError):
        b.neighbors_out[0, 0] = 99


# ----------------------------------------------------- derived structures


@pytest.mark.parametrize("p", [2, 3, 5, 11, 17, 36])
def test_neighbors_tables(p):
    b = get_bundle(p)
    for r in range(p):
        for k in range(b.q):
            assert b.neighbors_out[r][k] == (r + b.skips[k]) % p
            assert b.neighbors_in[r][k] == (r - b.skips[k]) % p
    # every round's edge set is a perfect matching of senders to receivers
    for k in range(b.q):
        assert sorted(b.neighbors_out[:, k]) == list(range(p))


@pytest.mark.parametrize("p", [2, 5, 11, 17])
@pytest.mark.parametrize("n", [1, 2, 5, 9])
def test_round_plan_structure(p, n):
    b = get_bundle(p)
    plan = b.round_plan(n)
    assert len(plan) == b.rounds(n) == num_rounds(p, n)
    x = b.virtual_rounds(n)
    assert x == virtual_rounds(p, n)
    ks = [k for k, _ in plan]
    assert ks[0] == x % b.q
    # k cycles through 0..q-1; offsets are multiples-of-q shifted by -x
    for i, (k, off) in enumerate(plan):
        assert k == (x + i) % b.q
        assert (off + x) % b.q == 0


@pytest.mark.parametrize("p", [2, 5, 11, 36])
@pytest.mark.parametrize("n", [1, 3, 8])
def test_adjusted_tables_match_algorithm1_folding(p, n):
    b = get_bundle(p)
    x = b.virtual_rounds(n)
    recv_adj, send_adj = b.adjusted_tables(n)
    for r in range(p):
        for i in range(b.q):
            d = b.q - x if i < x else -x
            assert recv_adj[r][i] == b.recv[r][i] + d
            assert send_adj[r][i] == b.send[r][i] + d
    # returned copies are writable (the simulator mutates them in place)
    recv_adj[0, 0] = 42


def test_jnp_tables_match_numpy():
    b = get_bundle(13)
    jr, js = b.jnp_tables()
    np.testing.assert_array_equal(np.asarray(jr), b.recv)
    np.testing.assert_array_equal(np.asarray(js), b.send)


def test_engine_drives_simulator_all_roots():
    from repro.core.simulator import simulate_broadcast

    for p in [3, 5, 11, 36]:
        for root in {0, 1, p // 2, p - 1}:
            res = simulate_broadcast(p, 4, root=root)
            assert res.rounds == res.optimal_rounds
