"""Examples smoke tests: the demo scripts run against the public API.

The demos are documentation that executes -- these tests run them as
subprocesses exactly as the README tells users to, so the examples can
never drift from the API surface again (an API change that breaks a
demo breaks the suite).
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(script, *args, device_count=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    if device_count is not None:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={device_count}")
    res = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script), *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=timeout,
    )
    assert res.returncode == 0, (
        f"{script} failed:\n{res.stdout}\n{res.stderr}")
    return res.stdout


def test_quickstart_runs():
    out = run_example("quickstart.py", "9", "4")
    assert "verified" in out
    assert "comm plan/execute" in out
    assert out.strip().endswith("OK")


@pytest.mark.multidevice
def test_collective_demo_runs():
    out = run_example("collective_demo.py", device_count=8)
    assert "CollectivePlan broadcast" in out
    assert "pytree broadcast" in out
    assert "allgatherv" in out
    assert out.count("OK") >= 4