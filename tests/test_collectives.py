"""Multi-device tests for the circulant JAX collective family.

Each case runs tests/mp_worker.py in a subprocess with
``--xla_force_host_platform_device_count=p`` so the main pytest process
keeps its single-device view (required for the smoke tests).  All tests
here carry the ``multidevice`` marker (see pytest.ini); the schedule-only
fast lane runs ``pytest -q -m "not multidevice"``.  When the worker
cannot get p devices (a backend ignoring the forcing flag), it reports
SKIP and the test skips gracefully."""

import pytest

from conftest import run_worker

pytestmark = pytest.mark.multidevice


@pytest.mark.parametrize("p", [2, 5, 8])
def test_circulant_broadcast_multidevice(p):
    run_worker("broadcast", p)


@pytest.mark.parametrize("p", [2, 5, 8])
def test_circulant_allgather_multidevice(p):
    run_worker("allgather", p)


@pytest.mark.parametrize("p", [3, 8])
def test_circulant_allgatherv_multidevice(p):
    run_worker("allgatherv", p)


def test_ring_allgather_multidevice():
    run_worker("ring", 8)


@pytest.mark.parametrize("p", [3, 8])
def test_restore_broadcast_multidevice(p):
    run_worker("restore", p)


@pytest.mark.parametrize("p", [4, 8])
def test_compressed_allreduce_multidevice(p):
    run_worker("compressed", p)


def test_compressed_allreduce_pallas_multidevice():
    run_worker("compressed", 4, backend="pallas")


@pytest.mark.parametrize("p", [2, 4])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_gradsync_parity_multidevice(p, backend):
    """grad_sync='compressed' vs 'auto': bounded loss-trajectory
    divergence over 20 optimizer steps (end-to-end trainer path)."""
    run_worker("gradsync", p, backend=backend)


@pytest.mark.parametrize("p", [3, 5, 8])
def test_circulant_reduce_scatter_multidevice(p):
    run_worker("reducescatter", p)


@pytest.mark.parametrize("p", [2, 5, 8])
def test_circulant_reduce_multidevice(p):
    run_worker("reduce", p)


@pytest.mark.parametrize("p", [2, 5, 8])
def test_circulant_allreduce_multidevice(p):
    run_worker("allreduce", p)


@pytest.mark.parametrize("p", [3, 8])
def test_circulant_allbroadcast_multidevice(p):
    run_worker("allbroadcast", p)


@pytest.mark.parametrize(
    "what", ["broadcast", "allgather", "allgatherv", "reduce", "allreduce"]
)
def test_collective_pallas_backend_multidevice(what):
    """The Pallas (interpret) round-step backend inside real shard_map
    collectives on a forced multi-device host mesh."""
    run_worker(what, 5, backend="pallas")


def test_reduce_scatter_reversal_property():
    """Beyond-paper: the time-reversed Algorithm-2 schedule is an exact
    reduce-scatter, checked combinatorially for many (p, n)."""
    import numpy as np

    from repro.core.schedule import (
        ceil_log2, compute_skips, recv_schedule, virtual_rounds,
    )

    rng = np.random.default_rng(0)
    for p in [2, 3, 5, 8, 13, 17, 33]:
        for n in [1, 2, 5, 9]:
            q = ceil_log2(p)
            skip = compute_skips(p)
            recv = [recv_schedule(p, r) for r in range(p)]
            x = virtual_rounds(p, n)
            X = rng.integers(0, 100, size=(p, p, n)).astype(np.int64)
            P = np.concatenate([X.copy(), np.zeros((p, p, 1), np.int64)], axis=2)

            def slot(r_, j, k, off):
                e = recv[(r_ - j) % p][k] + off
                return min(e, n - 1) if e >= 0 else None

            for i in reversed(range(x, n + q - 1 + x)):
                k = i % q
                off = q * ((i - k) // q) - x
                msgs = []
                for t in range(p):
                    payload = np.zeros((p,), np.int64)
                    for j in range(p):
                        s = slot(t, j, k, off)
                        if s is not None:
                            payload[j] = P[t, j, s]
                    msgs.append((t, (t - skip[k]) % p, payload))
                for t, dst, payload in msgs:
                    for j in range(p):
                        s = slot(t, j, k, off)
                        if s is not None:
                            P[t, j, s] = 0
                for t, dst, payload in msgs:
                    for j in range(p):
                        s = slot((dst + skip[k]) % p, j, k, off)
                        if s is not None:
                            P[dst, j, s] += payload[j]
            expect = X.sum(axis=0)
            for r in range(p):
                assert np.array_equal(P[r, r, :n], expect[r]), (p, n, r)
