"""Equivalence properties of the attention implementations:

* MLA absorbed decode == materialized full attention at the same position
  (the absorbed form folds W_uk/W_uv through the latent cache; both must
  produce identical outputs),
* GQA decode chain == full causal attention row-by-row,
* SSM single-step recurrence == chunked scan at the same position.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import (
    gqa_decode,
    gqa_full,
    gqa_init,
    mla_decode,
    mla_full,
    mla_init,
)
from repro.models.common import MLAConfig, ModelConfig, SSMConfig
from repro.models.ssm import ssm_block, ssm_init


def test_mla_absorbed_decode_matches_materialized():
    cfg = ModelConfig(
        name="mla-test", family="moe", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=128,
        mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        dtype="float32",
    )
    key = jax.random.PRNGKey(0)
    p = mla_init(key, cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    full_out, _ = mla_full(p, x, cfg, positions)

    # decode step-by-step with the compressed cache
    ckv = jnp.zeros((B, S, cfg.mla.kv_lora_rank), jnp.float32)
    kr = jnp.zeros((B, S, cfg.mla.qk_rope_dim), jnp.float32)
    outs = []
    for t in range(S):
        o, ckv, kr = mla_decode(p, x[:, t : t + 1], cfg, ckv, kr,
                                jnp.full((B,), t, jnp.int32))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_out), atol=2e-4, rtol=2e-4
    )


@pytest.mark.parametrize("window", [None, 4])
def test_gqa_decode_matches_full(window):
    cfg = ModelConfig(
        name="gqa-test", family="dense", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=128, sliding_window=window,
        dtype="float32",
    )
    p = gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    full_out, _ = gqa_full(p, x, cfg, positions)

    ck = jnp.zeros((B, S, cfg.n_kv_heads, cfg.hd), jnp.float32)
    cv = jnp.zeros_like(ck)
    outs = []
    for t in range(S):
        o, ck, cv = gqa_decode(p, x[:, t : t + 1], cfg, ck, cv,
                               jnp.full((B,), t, jnp.int32))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_out), atol=2e-4, rtol=2e-4
    )


def test_ssm_decode_matches_chunked():
    cfg = ModelConfig(
        name="ssm-test", family="ssm", n_layers=1, d_model=32, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab=64,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                      n_groups=1, chunk=4),
        dtype="float32",
    )
    p = ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 11
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    full = ssm_block(p, x, cfg)

    d_in = cfg.ssm.expand * cfg.d_model
    nh = d_in // cfg.ssm.head_dim
    cch = d_in + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
    conv = jnp.zeros((B, cfg.ssm.d_conv - 1, cch), jnp.float32)
    ssd = jnp.zeros((B, nh, cfg.ssm.d_state, cfg.ssm.head_dim), jnp.float32)
    outs = []
    for t in range(S):
        y, conv, ssd = ssm_block(p, x[:, t : t + 1], cfg,
                                 conv_state=conv, ssd_state=ssd)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)
