"""Tests for the two-level hierarchical collective layer (repro.core.hier).

In-process tests cover everything that needs no devices: communicator
validation, the p=1 fast path (a 1x1 mesh works in the main process),
plan-cache identity / collision / eviction-free growth across mixed
hierarchical and flat specs, the composed closed-form round counts, and
the hierarchical simulator certification grid -- including the paper's
36x32 evaluation topology on BOTH round-step backends (the acceptance
bar for this layer).

The multidevice-marked tests run ``tests/mp_worker.py hier`` in a
subprocess on forced 2x2 / 2x4 host meshes: dict/mixed-dtype pytrees
through all four hierarchical kinds on both backends, plus the
degenerate 1xp mesh equivalence with the flat collectives.
"""

import os
import sys

import numpy as np
import pytest

from conftest import run_worker

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def _mesh11():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("node", "core"))


# ------------------------------------------------------------- validation


def test_hier_comm_validates_axes_and_backend():
    from repro.core.hier import HierComm

    mesh = _mesh11()
    with pytest.raises(ValueError, match="axis"):
        HierComm(mesh=mesh, inter_axis="rack", intra_axis="core")
    with pytest.raises(ValueError, match="axis"):
        HierComm(mesh=mesh, inter_axis="node", intra_axis="rack")
    with pytest.raises(ValueError, match="differ"):
        HierComm(mesh=mesh, inter_axis="node", intra_axis="node")
    with pytest.raises(ValueError, match="backend"):
        HierComm(mesh=mesh, inter_axis="node", intra_axis="core",
                 backend="cuda")


def test_hier_plan_validates_arguments():
    from repro.core.hier import get_hier_comm

    hc = get_hier_comm(_mesh11(), "node", "core")
    x = {"a": np.zeros((1, 8), np.float32)}
    with pytest.raises(ValueError, match="kind"):
        hc.plan("gossip", x)
    with pytest.raises(ValueError, match="root"):
        hc.plan("allgather", x, root=1)
    with pytest.raises(ValueError, match="op"):
        hc.plan("broadcast", x, op="max")
    with pytest.raises(ValueError, match="root"):
        hc.plan("broadcast", x, root=7)  # out of [0, nodes*cores)


def test_hier_rounds_closed_form_and_validation():
    from repro.core.hier import hier_rounds
    from repro.core.schedule import num_rounds

    assert hier_rounds("broadcast", 36, 32, 4, 3) == (
        num_rounds(36, 4) + num_rounds(32, 3))
    assert hier_rounds("allreduce", 36, 32, 4, 3) == 2 * (
        num_rounds(36, 4) + num_rounds(32, 3))
    # the family alias canonicalizes
    assert hier_rounds("allbroadcast", 6, 4, 2, 2) == hier_rounds(
        "allgather", 6, 4, 2, 2)
    # degenerate levels contribute zero rounds
    assert hier_rounds("broadcast", 1, 8, 5, 3) == num_rounds(8, 3)
    assert hier_rounds("reduce", 8, 1, 3, 5) == num_rounds(8, 3)
    with pytest.raises(ValueError, match="kind"):
        hier_rounds("gossip", 2, 2, 1, 1)


def test_hier_p1_fast_path_identity_pytree():
    import jax

    from repro.core.hier import get_hier_comm

    hc = get_hier_comm(_mesh11(), "node", "core")
    state = {"w": np.arange(12, dtype=np.float32).reshape(1, 12),
             "b": (np.arange(5, dtype=np.int32).reshape(1, 5),)}
    for kind in ("broadcast", "reduce", "allreduce", "allgather"):
        plan = hc.plan(kind, state)
        assert plan.p == 1 and plan.rounds == 0
        out = plan(state)
        assert jax.tree.structure(out) == jax.tree.structure(state)
        np.testing.assert_array_equal(out["w"], state["w"])
    # mismatched payloads are rejected by the shared validator
    plan = hc.plan("broadcast", state)
    with pytest.raises(ValueError, match="tree"):
        plan({"x": state["w"]})
    with pytest.raises(ValueError, match="leaf"):
        plan({"w": state["w"].astype(np.float64), "b": state["b"]})


# ------------------------------------ plan-cache identity / growth audit


def test_hier_plan_cache_identity_and_eviction_free_growth():
    """Eviction-free growth across mixed hier+flat specs: repeated
    planning never grows the cache (pure hits), distinct specs add
    exactly their own entries, and nothing is ever evicted."""
    from repro.core.comm import host_plan
    from repro.core.engine import plan_cache_info, plan_cache_keys
    from repro.core.hier import get_hier_comm, hier_host_plan

    hc = get_hier_comm(_mesh11(), "node", "core")
    x = {"a": np.zeros((1, 8), np.float32)}
    p1 = hc.plan("broadcast", x, n_inter=2, n_intra=2)
    keys_before = set(plan_cache_keys())
    info_before = plan_cache_info()
    # pure replanning: identity, zero growth
    for _ in range(5):
        assert hc.plan("broadcast", x, n_inter=2, n_intra=2) is p1
    assert plan_cache_info()["size"] == info_before["size"]
    assert plan_cache_info()["hits"] >= info_before["hits"] + 5
    # the alias kind canonicalizes onto the same entry
    assert hc.plan("allbroadcast", x) is hc.plan("allgather", x)
    # mixed hier + flat specs with the same numeric parameters coexist:
    # namespaced keys cannot collide, so each adds its own entries and
    # evicts nothing
    hp_flat = host_plan("broadcast", 6, 2)
    hp_hier = hier_host_plan("broadcast", 6, 2, 2, 2)
    assert hp_flat is not hp_hier
    assert hp_flat is host_plan("broadcast", 6, 2)
    assert hp_hier is hier_host_plan("broadcast", 6, 2, 2, 2)
    keys_after = set(plan_cache_keys())
    assert keys_before <= keys_after, "plan cache evicted entries"
    assert len(keys_after) == plan_cache_info()["size"]
    # every key is namespaced by a distinct leading tag
    tags = {k[0] for k in keys_after if isinstance(k, tuple)}
    assert tags <= {"commplan", "hierplan", "hostplan", "hierhostplan",
                    "comm", "hiercomm", "slots/bcast", "slots/reduce",
                    "slots/scatter"}, tags


def test_hier_and_flat_host_plans_do_not_collide():
    """A hier host plan over (p, 1) and the flat host plan over p share
    per-level flat entries but keep distinct top-level identities."""
    from repro.core.comm import host_plan
    from repro.core.hier import hier_host_plan

    flat = host_plan("broadcast", 9, 3)
    hier = hier_host_plan("broadcast", 9, 1, 3, 1)
    assert flat is not hier
    # the hier plan's inter level IS the cached flat plan (shared entry)
    assert hier.inter is flat
    vals = np.arange(6, dtype=np.int64)
    got = hier.run(vals)
    assert got.shape == (9, 1, 6)
    for j in range(9):
        np.testing.assert_array_equal(got[j, 0], vals)


def test_hier_comm_cached_identity():
    from repro.core.costmodel import CommModel
    from repro.core.hier import get_hier_comm

    mesh = _mesh11()
    h1 = get_hier_comm(mesh, "node", "core")
    assert h1 is get_hier_comm(mesh, "node", "core")
    assert h1 is not get_hier_comm(mesh, "node", "core", backend="pallas")
    assert h1 is not get_hier_comm(
        mesh, "node", "core", inter_model=CommModel(alpha=5e-5))


def test_optimal_hier_blocks_per_level_decoupling():
    from repro.core.costmodel import (
        CommModel,
        hier_cost,
        optimal_hier_blocks,
        optimal_num_blocks_bcast,
    )

    slow = CommModel(alpha=2e-5, beta=1e-9)    # inter-node: latency-heavy
    fast = CommModel(alpha=5e-7, beta=2e-11)   # intra-node
    m = 1 << 22
    nN, nC = optimal_hier_blocks(36, 32, m, m, slow, fast)
    assert nN == optimal_num_blocks_bcast(36, m, slow)
    assert nC == optimal_num_blocks_bcast(32, m, fast)
    # the two-level cost at the optimum beats obviously bad block counts
    best = hier_cost("broadcast", 36, 32, m, m, nN, nC, slow, fast)
    assert best <= hier_cost("broadcast", 36, 32, m, m, 1, 1, slow, fast)
    assert best <= hier_cost("broadcast", 36, 32, m, m, m, m, slow, fast)
    with pytest.raises(ValueError, match="kind"):
        optimal_hier_blocks(2, 2, 8, 8, kind="gossip")
    with pytest.raises(ValueError, match="kind"):
        hier_cost("gossip", 2, 2, 8, 8, 1, 1)


# ------------------------------------------- simulator certification grid


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_simulate_hier_certification_grid(backend):
    """Hier broadcast/reduce/allreduce certify bit-exact against the
    composed host data plane over a (nodes x cores) grid, both
    backends, with composed round counts asserted internally."""
    from repro.core import (
        simulate_hier_allreduce,
        simulate_hier_broadcast,
        simulate_hier_reduce,
    )

    for nodes, cores in [(1, 1), (1, 5), (5, 1), (2, 3), (4, 4), (3, 8)]:
        for nN, nC in [(1, 2), (2, 3)]:
            root = (nodes * cores) // 2
            simulate_hier_broadcast(nodes, cores, nN, nC, root=root,
                                    backend=backend)
            simulate_hier_reduce(nodes, cores, nN, nC, root=root,
                                 backend=backend)
        simulate_hier_allreduce(nodes, cores, 2, 2, backend=backend)
    simulate_hier_reduce(3, 4, 2, 2, op="max", backend=backend)
    simulate_hier_allreduce(2, 4, 1, 2, op="max", backend=backend)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_simulate_hier_36x32_paper_topology(backend):
    """The paper's full 36x32 evaluation topology certifies on both
    backends: composed optimum round counts and bit-exact data planes
    (1152 simulated ranks -- far beyond any local device mesh)."""
    from repro.core import (
        simulate_hier_allreduce,
        simulate_hier_broadcast,
        simulate_hier_reduce,
    )
    from repro.core.schedule import num_rounds

    r = simulate_hier_broadcast(36, 32, 3, 2, root=35 * 32 + 7,
                                backend=backend)
    assert (r.rounds, r.rounds_inter, r.rounds_intra) == (
        r.optimal_rounds, num_rounds(36, 3), num_rounds(32, 2))
    r = simulate_hier_reduce(36, 32, 2, 2, root=100, backend=backend)
    assert r.rounds == r.optimal_rounds
    r = simulate_hier_allreduce(36, 32, 2, 1, backend=backend)
    assert r.rounds == r.optimal_rounds


def test_simulate_hier_float_sum_and_custom_values():
    """Float sums certify against the schedule-order data plane; int
    payload shape/divisibility validation raises."""
    from repro.core import simulate_hier_reduce

    rng = np.random.default_rng(3)
    vals = rng.normal(size=(3, 4, 12)).astype(np.float64)
    r = simulate_hier_reduce(3, 4, 2, 3, values=vals, backend="jnp")
    np.testing.assert_allclose(
        np.asarray(r.buffers[0]), vals.reshape(12, 12).sum(axis=0),
        rtol=1e-12)
    with pytest.raises(AssertionError, match="divide"):
        simulate_hier_reduce(2, 2, 2, 3, values=np.zeros((2, 2, 7)))


def test_hier_host_plan_validates():
    from repro.core.hier import hier_host_plan

    with pytest.raises(ValueError, match="kind"):
        hier_host_plan("gossip", 2, 2, 1, 1)
    with pytest.raises(ValueError, match="root"):
        hier_host_plan("broadcast", 2, 2, 1, 1, root=4)


# --------------------------------------------------- multidevice grid


@pytest.mark.multidevice
@pytest.mark.parametrize("nodes,cores", [(2, 2), (2, 4)])
def test_hier_pytree_multidevice(nodes, cores):
    """Dict/mixed-dtype pytrees through all four hierarchical kinds on
    a real (forced) 2D device mesh, jnp data plane."""
    run_worker("hier", nodes * cores, "jnp", nodes)


@pytest.mark.multidevice
def test_hier_pytree_multidevice_pallas():
    """The same grid through the fused Pallas (interpret) data plane."""
    run_worker("hier", 4, "pallas", 2)
