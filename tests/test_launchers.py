"""End-to-end launcher tests: train + serve on a real (host-device) mesh
in subprocesses, including checkpoint auto-resume across restarts."""

import os
import subprocess
import sys
import tempfile

import pytest

pytestmark = pytest.mark.multidevice

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(mod, extra, devices=8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run(
        [sys.executable, "-m", mod] + extra,
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert res.returncode == 0, f"{mod} failed:\n{res.stdout}\n{res.stderr}"
    return res.stdout


def test_train_launcher_and_resume():
    with tempfile.TemporaryDirectory() as ck:
        out = _run("repro.launch.train",
                   ["--arch", "qwen2-0.5b", "--smoke", "--mesh", "4x2",
                    "--steps", "10", "--ckpt-dir", ck, "--ckpt-every", "5"])
        assert "done: 10 steps" in out
        out2 = _run("repro.launch.train",
                    ["--arch", "qwen2-0.5b", "--smoke", "--mesh", "4x2",
                     "--steps", "12", "--ckpt-dir", ck, "--ckpt-every", "5"])
        assert "resumed from step 10" in out2
        assert "done: 2 steps" in out2


def test_serve_launcher():
    out = _run("repro.launch.serve",
               ["--arch", "qwen2-0.5b", "--smoke", "--mesh", "4x2",
                "--batch", "4", "--steps", "6"])
    assert "OK" in out


def test_dryrun_input_specs_all_cells():
    """input_specs() (the dry-run contract) builds for every cell."""
    import jax

    from repro.configs import all_arch_names
    from repro.launch.dryrun import LONG_OK, input_specs
    from repro.models.common import SHAPES

    for arch in all_arch_names():
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            specs = input_specs(arch, shape)
            assert "tokens" in specs
            for leaf in jax.tree.leaves(specs):
                assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
