"""Pallas kernel tests: interpret-mode vs pure-jnp oracles, sweeping
shapes and dtypes (per-kernel allclose against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ops import (
    gqa_flash_attention,
    mamba2_ssd,
    schedule_acc_shuffle,
    schedule_pack,
    schedule_qacc_shuffle,
    schedule_shuffle,
    schedule_unpack,
)
from repro.kernels.ref import (
    attention_ref,
    block_acc_shuffle_ref,
    block_pack_ref,
    block_qacc_shuffle_ref,
    block_shuffle_ref,
    block_unpack_ref,
    ssd_ref,
)
from repro.kernels.ssd_scan import ssd_scan
from repro.models.attention import blocked_attention
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ------------------------------------------------------------ flash attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,Hkv,hd,bq,bk",
    [
        (1, 64, 4, 4, 32, 32, 32),      # MHA
        (2, 100, 4, 2, 32, 32, 32),     # GQA, ragged seq
        (1, 128, 8, 2, 16, 64, 32),     # rep=4
        (2, 37, 2, 1, 64, 16, 16),      # odd seq
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, B, S, H, Hkv, hd, bq, bk, causal):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), dtype)
    out = gqa_flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = blocked_attention(q, k, v, causal, None, 0, 1024, 1024)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_flash_attention_sliding_window():
    B, S, H, hd = 1, 96, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    for w in (8, 33):
        out = gqa_flash_attention(q, k, v, causal=True, window=w,
                                  block_q=32, block_k=32)
        ref = blocked_attention(q, k, v, True, w, 0, 1024, 1024)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_mla_vdim():
    # value head dim != qk head dim (MLA)
    B, S, H, hd, hdv = 1, 64, 2, 32, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, hdv)), jnp.float32)
    out = gqa_flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = blocked_attention(q, k, v, True, None, 0, 1024, 1024)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 3), st.integers(8, 70), st.sampled_from([1, 2, 4]),
    st.sampled_from([8, 16, 32]), st.booleans(),
)
def test_flash_attention_hypothesis(B, S, rep, hd, causal):
    Hkv = 2
    H = Hkv * rep
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    out = gqa_flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    ref = blocked_attention(q, k, v, causal, None, 0, 1024, 1024)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ------------------------------------------------------------- pack/unpack


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("R,ns,bs", [(4, 3, 8), (8, 9, 128), (17, 6, 32)])
def test_block_pack_unpack(dtype, R, ns, bs):
    if dtype == jnp.int32:
        buf = jnp.asarray(RNG.integers(0, 100, size=(R, ns, bs)), dtype)
        msg = jnp.asarray(RNG.integers(0, 100, size=(R, bs)), dtype)
    else:
        buf = jnp.asarray(RNG.normal(size=(R, ns, bs)), dtype)
        msg = jnp.asarray(RNG.normal(size=(R, bs)), dtype)
    idx = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(schedule_pack(buf, idx)), np.asarray(block_pack_ref(buf, idx))
    )
    np.testing.assert_array_equal(
        np.asarray(schedule_unpack(buf, msg, idx)),
        np.asarray(block_unpack_ref(buf, msg, idx)),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("R,ns,bs", [(4, 3, 8), (8, 9, 128), (17, 6, 32)])
def test_block_shuffle(dtype, R, ns, bs):
    """Fused unpack+pack vs the jnp oracle, incl. the recv==send pipeline."""
    if dtype == jnp.int32:
        buf = jnp.asarray(RNG.integers(0, 100, size=(R, ns, bs)), dtype)
        msg = jnp.asarray(RNG.integers(0, 100, size=(R, bs)), dtype)
    else:
        buf = jnp.asarray(RNG.normal(size=(R, ns, bs)), dtype)
        msg = jnp.asarray(RNG.normal(size=(R, bs)), dtype)
    recv = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    send = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    send = send.at[0].set(recv[0])  # forward what was just received
    kb, km = schedule_shuffle(buf, msg, recv, send)
    rb, rm = block_shuffle_ref(buf, msg, recv, send)
    np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("R,ns,bs", [(4, 3, 8), (17, 6, 32)])
def test_block_acc_shuffle(op, dtype, R, ns, bs):
    """Fused accumulate+capture/drain vs the jnp oracle, incl. acc==fwd."""
    if dtype == jnp.int32:
        buf = jnp.asarray(RNG.integers(-100, 100, size=(R, ns, bs)), dtype)
        msg = jnp.asarray(RNG.integers(-100, 100, size=(R, bs)), dtype)
    else:
        buf = jnp.asarray(RNG.normal(size=(R, ns, bs)), dtype)
        msg = jnp.asarray(RNG.normal(size=(R, bs)), dtype)
    acc = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    fwd = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    fwd = fwd.at[0].set(acc[0])  # capped re-send: capture the fresh partial
    kb, km = schedule_acc_shuffle(buf, msg, acc, fwd, op=op)
    rb, rm = block_acc_shuffle_ref(buf, msg, acc, fwd, op=op)
    np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))


@pytest.mark.parametrize("R,ns,nb,qb", [(4, 4, 3, 8), (8, 6, 2, 128),
                                        (17, 5, 4, 16)])
def test_block_qacc_shuffle(R, ns, nb, qb):
    """Quantized accumulate+requantize/capture/drain vs the JITTED jnp
    oracle, bit-for-bit -- the jit matters: both lower the error
    capture to a fused multiply-add, which the eager oracle does not.
    Covers acc==fwd coincidence and NaN-flagged scale blocks."""
    bs = nb * qb
    buf = jnp.asarray(
        (RNG.normal(size=(R, ns, bs)) *
         10.0 ** RNG.integers(-3, 4, size=(R, ns, 1))), jnp.float32)
    err = jnp.asarray(RNG.normal(size=(R, ns, bs)) * 1e-3, jnp.float32)
    qmsg = jnp.asarray(RNG.integers(-127, 128, size=(R, bs)), jnp.int8)
    smsg = jnp.asarray(10.0 ** RNG.uniform(-5, 2, size=(R, nb)), jnp.float32)
    smsg = smsg.at[1, 0].set(jnp.nan)       # flagged incoming block
    acc = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    fwd = jnp.asarray(RNG.integers(0, ns, size=R), jnp.int32)
    fwd = fwd.at[0].set(acc[0])             # capture the fresh partial
    acc = acc.at[1].set(1)                  # flagged row: acc != fwd so the
    fwd = fwd.at[1].set(2)                  # poisoned slot survives drain
    out = schedule_qacc_shuffle(buf, err, qmsg, smsg, acc, fwd)
    ref = jax.jit(block_qacc_shuffle_ref)(buf, err, qmsg, smsg, acc, fwd)
    for k, r in zip(out, ref):
        np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    # NaN flag propagated: the accumulated slot of row 1 contains the
    # poisoned first quant-block
    nb_buf = np.asarray(out[0])
    assert np.isnan(nb_buf[1, int(acc[1])][:qb]).all()
    assert np.isfinite(np.asarray(out[1])).all()  # error never poisoned


def test_block_pack_with_real_schedule():
    """Pack driven by an actual send schedule from the paper's algorithm."""
    from repro.core.schedule import compute_skips, send_schedule, ceil_log2

    p = 17
    q = ceil_log2(p)
    n = 7
    bs = 16
    # one rank's buffers: n blocks + garbage slot
    buf = jnp.asarray(RNG.normal(size=(q, n + 1, bs)), jnp.float32)
    sched = send_schedule(p, 5)
    idx = jnp.asarray(
        [n if s < 0 else min(s, n - 1) for s in sched], jnp.int32
    )
    out = schedule_pack(buf, idx)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(block_pack_ref(buf, idx))
    )


# --------------------------------------------------------------- ssd scan


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize(
    "BH,S,P,N,chunk", [(2, 64, 8, 4, 16), (3, 70, 16, 8, 32), (1, 17, 4, 2, 8)]
)
def test_ssd_scan_sweep(dtype, BH, S, P, N, chunk):
    x = jnp.asarray(RNG.normal(size=(BH, S, P)), dtype)
    B_ = jnp.asarray(RNG.normal(size=(BH, S, N)), dtype)
    C_ = jnp.asarray(RNG.normal(size=(BH, S, N)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(BH, S)), jnp.float32)
    alog = jnp.asarray(np.log(RNG.uniform(0.5, 2, size=(BH,))), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(BH,)), jnp.float32)
    out = ssd_scan(x, B_, C_, dt, alog, D, chunk=chunk)
    ref = ssd_ref(x, B_, C_, dt, alog, D)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref, x.dtype), atol=1e-4, rtol=1e-4
    )


def test_mamba2_ssd_wrapper_matches_model_chunked():
    B, S, H, P, G, N = 2, 48, 4, 8, 2, 4
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    B_ = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    C_ = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    alog = jnp.asarray(np.log(RNG.uniform(0.5, 2, size=(H,))), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    out = mamba2_ssd(x, B_, C_, dt, alog, D, chunk=16)
    ref = ssd_chunked(x, B_, C_, dt, alog, D, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
