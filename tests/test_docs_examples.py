"""Docs check: every ```python block in docs/*.md (and README.md) runs,
and every relative markdown link resolves.

Blocks within one file execute sequentially in a shared namespace, so
later examples may build on earlier imports/variables exactly as a
reader would run them top to bottom.  Fenced languages other than
``python`` (bash, text, ...) are ignored.  The link checker covers
``[text](target)`` links to repo-relative files (external URLs and
in-page anchors are skipped), so docs cross-references cannot rot
either.  The CI docs job runs this file standalone.
"""

import os
import re
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

_FENCE = re.compile(r"^```(\w*)\s*$")


def _doc_files():
    docs_dir = os.path.join(ROOT, "docs")
    files = [os.path.join(ROOT, "README.md")]
    if os.path.isdir(docs_dir):
        files += sorted(
            os.path.join(docs_dir, f)
            for f in os.listdir(docs_dir)
            if f.endswith(".md")
        )
    return [f for f in files if os.path.exists(f)]


def extract_python_blocks(path):
    """[(start_line, source), ...] for every ```python fence in the file."""
    blocks = []
    lang, buf, start = None, [], 0
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            m = _FENCE.match(line.strip())
            if m and lang is None:
                lang, buf, start = m.group(1) or "text", [], lineno + 1
            elif line.strip() == "```" and lang is not None:
                if lang == "python":
                    blocks.append((start, "".join(buf)))
                lang = None
            elif lang is not None:
                buf.append(line)
    return blocks


_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _strip_fences(text):
    """Drop fenced code blocks so code samples can't trip the link check."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.strip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


@pytest.mark.parametrize(
    "path", _doc_files(), ids=lambda p: os.path.relpath(p, ROOT)
)
def test_docs_links_resolve(path):
    """Every repo-relative markdown link points at an existing file."""
    text = _strip_fences(open(path, encoding="utf-8").read())
    base = os.path.dirname(path)
    broken = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#")[0]
        if not target:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            broken.append(target)
    assert not broken, (
        f"{os.path.relpath(path, ROOT)}: broken relative links {broken}"
    )


@pytest.mark.parametrize(
    "path", _doc_files(), ids=lambda p: os.path.relpath(p, ROOT)
)
def test_docs_code_blocks_execute(path):
    blocks = extract_python_blocks(path)
    if not blocks:
        pytest.skip(f"no python blocks in {os.path.relpath(path, ROOT)}")
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    ns = {"__name__": "__docs__"}
    for start, src in blocks:
        code = compile(src, f"{os.path.relpath(path, ROOT)}:{start}", "exec")
        exec(code, ns)  # noqa: S102 - executing our own documentation
