"""Optional-hypothesis shim: property tests degrade to deterministic smoke.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When
it is installed, this module re-exports the real ``given`` / ``settings``
/ ``strategies``.  When it is not, a miniature deterministic sampler
stands in: each ``@given`` test runs a fixed number of pseudo-random
examples drawn from a generator seeded with the test's qualified name,
so collection never fails and the property still gets exercised (just
without shrinking or the full search).

Only the strategy combinators the test-suite actually uses are
implemented (integers, sampled_from, booleans).
"""

try:  # pragma: no cover - trivially one branch per environment
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class st:  # noqa: N801 - mimics the hypothesis.strategies module name
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    def settings(*args, **kwargs):
        def deco(f):
            return f

        return deco

    def given(*strategies, **kw_strategies):
        assert not kw_strategies, "fallback shim supports positional strategies"

        def deco(f):
            # No functools.wraps: pytest must see a zero-argument callable,
            # not the strategy-typed signature of the wrapped property.
            def runner():
                rng = random.Random(f.__qualname__)
                for _ in range(_FALLBACK_EXAMPLES):
                    f(*(s.example(rng) for s in strategies))

            runner.__name__ = f.__name__
            runner.__doc__ = f.__doc__
            runner.__module__ = f.__module__
            return runner

        return deco
