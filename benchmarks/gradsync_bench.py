"""Gradient-sync benchmark: quantized circulant vs int8 ring vs GSPMD.

    PYTHONPATH=src python -m benchmarks.run gradsync

Compares the three gradient synchronisation transports the trainer can
use -- GSPMD 'auto' (f32 ``lax.pmean``), the legacy int8 ring
(``compressed_psum_ring``) and the quantized circulant allreduce
(``circulant_qallreduce_body``) -- and writes ``BENCH_gradsync.json``
at the repo root (committed, so the numbers version with the code).

Committed JSON schema (``schema: 1``; times are medians over iters):

    {
      "schema": 1,
      "note": ...,                    # honest caveat about the testbed
      "model": [                      # analytic, no devices needed
        {"p": ..., "m_bytes": ...,    # payload per rank, f32 bytes
         "rounds_ring": ...,          # 2(p-1)
         "rounds_circulant": ...,     # 2(n-1) + 2 ceil(log2 p)
         "n_blocks": ...,
         "wire_f32_gspmd": ...,       # bytes shipped per rank, f32 ring
         "wire_int8_ring": ...,       # int8 payload + f32 block scales
         "wire_int8_circulant": ...,
         "wire_reduction_vs_f32": ...},
        ...
      ],
      "device": [                     # subprocess, forced host devices
        {"p": ..., "m_bytes": ...,
         "gspmd_auto_us": ...,        # jitted shard_map lax.pmean
         "ring_int8_us": ...,         # compressed_psum_ring w/ EF capture
         "circulant_int8_us": ...,    # circulant_qallreduce_body (jnp)
         "winner": ...},              # fastest of the three, honestly
        ...
      ]
    }

The ``device`` rows come from XLA host devices on one CPU: there is no
real interconnect, so int8-on-the-wire saves no transfer time there and
the quantize/dequantize arithmetic is pure overhead -- GSPMD 'auto'
winning these rows is expected and reported as-is.  The bandwidth claim
of the quantized path lives in the ``model`` rows (4x fewer wire bytes
at the same round count as the f32 circulant schedule); the ``device``
rows bound the compute-side cost of compression and check that the
circulant data plane stays in the same regime as the legacy ring.
"""

from __future__ import annotations

import json
import math
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_gradsync.json")

CASES = [(8, 262144), (8, 2097152)]  # (p, m_bytes of f32 payload per rank)


def model_rows():
    """Analytic round/wire-volume model -- the actual bandwidth claim."""
    from repro.core.costmodel import DEFAULT_MODEL, optimal_num_blocks_reduce
    from repro.kernels.quant_ops import QBLOCK

    rows = []
    for p, m in CASES:
        elems = m // 4
        n = max(1, optimal_num_blocks_reduce(p, elems, DEFAULT_MODEL))
        n = min(n, max(1, -(-elems // QBLOCK)))
        rounds_ring = 2 * (p - 1)
        rounds_circ = 2 * (n - 1) + 2 * math.ceil(math.log2(p))
        # Bytes shipped per rank: ring reduce-scatter + all-gather each
        # move (p-1) segments of m/p; the circulant schedule moves one
        # block of m/n per round.  int8 payloads carry one f32 scale per
        # QBLOCK elements.
        scale_overhead = 1.0 + 4.0 / QBLOCK
        wire_f32 = 2 * (p - 1) * (m // p)
        wire_ring = int(2 * (p - 1) * (elems // p) * scale_overhead)
        wire_circ = int(rounds_circ * (elems / n) * scale_overhead)
        rows.append({
            "p": p,
            "m_bytes": m,
            "n_blocks": n,
            "rounds_ring": rounds_ring,
            "rounds_circulant": rounds_circ,
            "wire_f32_gspmd": wire_f32,
            "wire_int8_ring": wire_ring,
            "wire_int8_circulant": wire_circ,
            "wire_reduction_vs_f32": round(wire_f32 / wire_circ, 2),
        })
    return rows


_DEVICE_CODE = r"""
import json, time, numpy as np, jax, jax.numpy as jnp
from functools import partial
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.jaxcompat import shard_map
from repro.core.comm import circulant_qallreduce_body
from repro.optim.compression import compressed_psum_ring

def median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]

p = len(jax.devices())
mesh = Mesh(np.array(jax.devices()), ("data",))
CASES = %s
rows = []
for pp, m in CASES:
    assert pp == p
    elems = m // 4
    rng = np.random.default_rng(0)
    x = jax.device_put(
        jnp.asarray(rng.standard_normal((p, elems)), jnp.float32),
        NamedSharding(mesh, P("data")))
    sm = partial(shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=P("data"), check_vma=False)

    @jax.jit
    @sm
    def gspmd_auto(a):
        return jax.lax.pmean(a, "data")

    @jax.jit
    @sm
    def ring_int8(a):
        mean, err = compressed_psum_ring(a[0], "data", p)
        return (mean + 0.0 * err)[None]

    @jax.jit
    @sm
    def circulant_int8(a):
        sums, errs = circulant_qallreduce_body([a[0]], "data", p,
                                               backend="jnp")
        return (sums[0] / p + 0.0 * errs[0])[None]

    row = {"p": p, "m_bytes": m}
    for name, fn in (("gspmd_auto", gspmd_auto), ("ring_int8", ring_int8),
                     ("circulant_int8", circulant_int8)):
        jax.block_until_ready(fn(x))  # compile once
        ts = []
        for _ in range(20):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            ts.append(time.perf_counter() - t0)
        row[name + "_us"] = round(median(ts) * 1e6, 1)
    row["winner"] = min(
        ("gspmd_auto", "ring_int8", "circulant_int8"),
        key=lambda k: row[k + "_us"])
    rows.append(row)
print("JSON" + json.dumps(rows))
"""


def device_rows(p: int = 8):
    """Time the three transports in a subprocess with p host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    code = _DEVICE_CODE % repr([(pp, m) for pp, m in CASES if pp == p])
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    for line in res.stdout.splitlines():
        if line.startswith("JSON"):
            return json.loads(line[4:])
    raise RuntimeError("gradsync device benchmark produced no JSON row")


NOTE = ("device rows are XLA host devices on one CPU (no interconnect): "
        "they bound compression compute overhead only; the bandwidth "
        "claim is the model rows' wire volumes")


def main(write_json: bool = True):
    model = model_rows()
    print("name,p,m_bytes,n_blocks,rounds_ring,rounds_circ,"
          "wire_f32,wire_ring,wire_circ,reduction")
    for r in model:
        print(f"gradsync_model,{r['p']},{r['m_bytes']},{r['n_blocks']},"
              f"{r['rounds_ring']},{r['rounds_circulant']},"
              f"{r['wire_f32_gspmd']},{r['wire_int8_ring']},"
              f"{r['wire_int8_circulant']},{r['wire_reduction_vs_f32']}")
    device = device_rows()
    print("name,p,m_bytes,gspmd_auto_us,ring_int8_us,circulant_int8_us,"
          "winner")
    for r in device:
        print(f"gradsync_device,{r['p']},{r['m_bytes']},"
              f"{r['gspmd_auto_us']},{r['ring_int8_us']},"
              f"{r['circulant_int8_us']},{r['winner']}")
    if write_json:
        payload = {"schema": 1, "note": NOTE, "model": model,
                   "device": device}
        with open(OUT_PATH, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {os.path.relpath(OUT_PATH, ROOT)}")


if __name__ == "__main__":
    main()
