"""Communicator benchmark: plan-once/execute-many vs per-call dispatch.

    PYTHONPATH=src python -m benchmarks.run comm

Measures the api_redesign's central claim -- plan construction is a
one-time host cost fully decoupled from execution -- and writes the
machine-readable perf trajectory to ``BENCH_comm.json`` at the repo
root (committed, so the numbers version with the code).

Committed JSON schema (``schema: 1``; times are medians over iters):

    {
      "schema": 1,
      "host": {                       # no devices needed
        "p": ..., "n": ...,
        "plan_cold_ms": ...,          # first host_plan: bundle + slot tables
        "plan_cached_us": ...,        # cached host_plan lookup
        "slotplan_cached_us": ...     # cached slot-table lookup
      },
      "device": [                     # subprocess, forced host devices
        {"kind": ..., "p": ..., "m_bytes": ..., "n_blocks": ...,
         "plan_us": ...,              # cached CollectivePlan.__call__
         "shim_us": ...,              # circulant_* shim (plan-cache lookup)
         "percall_ms": ...,           # legacy dispatch: plan rebuilt+retraced
         "speedup_plan_vs_percall": ...},
        ...
      ]
    }

``plan_us`` is the steady-state cost the plan/execute API pays per
call; ``percall_ms`` clears the plan cache before every call, which is
what each pre-communicator ``circulant_*`` invocation effectively did
(fresh closure -> slot-table rederivation + shard_map retrace +
recompile).  ``shim_us`` shows the shims riding the same plan cache.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_comm.json")

HOST_P, HOST_N = 1024, 64


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def host_rows(p: int = HOST_P, n: int = HOST_N):
    """Plan construction vs cached lookup, host-side only."""
    from repro.core.comm import host_plan
    from repro.core.engine import (
        bundle_cache_clear,
        get_bundle,
        plan_cache_clear,
    )
    from repro.core.roundstep import broadcast_slot_plan

    bundle_cache_clear()
    plan_cache_clear()
    t0 = time.perf_counter()
    host_plan("broadcast", p, n)
    plan_cold_ms = (time.perf_counter() - t0) * 1e3

    iters = 2000
    t0 = time.perf_counter()
    for _ in range(iters):
        host_plan("broadcast", p, n)
    plan_cached_us = (time.perf_counter() - t0) / iters * 1e6

    bundle = get_bundle(p)
    t0 = time.perf_counter()
    for _ in range(iters):
        broadcast_slot_plan(bundle, n)
    slotplan_cached_us = (time.perf_counter() - t0) / iters * 1e6

    return {
        "p": p,
        "n": n,
        "plan_cold_ms": round(plan_cold_ms, 3),
        "plan_cached_us": round(plan_cached_us, 2),
        "slotplan_cached_us": round(slotplan_cached_us, 2),
    }


_DEVICE_CODE = r"""
import json, time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.comm import get_comm
from repro.core.collectives import circulant_allreduce, circulant_broadcast
from repro.core.engine import plan_cache_clear

def median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]

p = len(jax.devices())
mesh = Mesh(np.array(jax.devices()), ("data",))
comm = get_comm(mesh, "data")
rows = []
CASES = [
    ("broadcast", 65536), ("broadcast", 1048576),
    ("allreduce", 65536),
]
for kind, m in CASES:
    n = 8
    elems = m // 4
    x = jax.device_put(jnp.zeros((p, elems), jnp.float32),
                       NamedSharding(mesh, P("data")))
    plan = comm.plan(kind, x, n_blocks=n)   # hoisted: plan once ...
    shim = circulant_broadcast if kind == "broadcast" else circulant_allreduce
    shim_fn = lambda a: shim(mesh, "data", a, n_blocks=n)
    jax.block_until_ready(plan(x))  # compile once
    ts = []
    for _ in range(30):
        t0 = time.perf_counter()
        jax.block_until_ready(plan(x))      # ... execute many
        ts.append(time.perf_counter() - t0)
    plan_us = median(ts) * 1e6
    jax.block_until_ready(shim_fn(x))
    ts = []
    for _ in range(30):
        t0 = time.perf_counter()
        jax.block_until_ready(shim_fn(x))
        ts.append(time.perf_counter() - t0)
    shim_us = median(ts) * 1e6
    ts = []
    for _ in range(3):  # legacy per-call dispatch: rebuild + retrace + compile
        plan_cache_clear()
        t0 = time.perf_counter()
        jax.block_until_ready(shim_fn(x))
        ts.append(time.perf_counter() - t0)
    percall_ms = median(ts) * 1e3
    rows.append({
        "kind": kind, "p": p, "m_bytes": m, "n_blocks": n,
        "plan_us": round(plan_us, 1), "shim_us": round(shim_us, 1),
        "percall_ms": round(percall_ms, 2),
        "speedup_plan_vs_percall": round(percall_ms * 1e3 / plan_us, 1),
    })
print("JSON" + json.dumps(rows))
"""


def device_rows(p: int = 8):
    """Run the on-device comparison in a subprocess with p host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _DEVICE_CODE], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    for line in res.stdout.splitlines():
        if line.startswith("JSON"):
            return json.loads(line[4:])
    raise RuntimeError("device benchmark produced no JSON row")


def main(write_json: bool = True):
    host = host_rows()
    print("name,p,n,plan_cold_ms,plan_cached_us,slotplan_cached_us")
    print(f"comm_host,{host['p']},{host['n']},{host['plan_cold_ms']},"
          f"{host['plan_cached_us']},{host['slotplan_cached_us']}")
    device = device_rows()
    print("name,kind,p,m_bytes,n_blocks,plan_us,shim_us,percall_ms,speedup")
    for r in device:
        print(f"comm_device,{r['kind']},{r['p']},{r['m_bytes']},"
              f"{r['n_blocks']},{r['plan_us']},{r['shim_us']},"
              f"{r['percall_ms']},{r['speedup_plan_vs_percall']}")
    if write_json:
        payload = {"schema": 1, "host": host, "device": device}
        with open(OUT_PATH, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {os.path.relpath(OUT_PATH, ROOT)}")


if __name__ == "__main__":
    main()
