"""Shared jnp-vs-pallas round-step timing sweep for the collective benches.

One timing methodology and CSV schema for both families, so the
broadcast (fused unpack+pack ``shuffle``) and all-reduce (fused
accumulate+capture/drain ``acc_shuffle``) sweeps cannot drift apart.
On CPU the pallas backend runs in interpret mode -- the comparison is
apples-to-apples only on TPU, but the sweep certifies the plumbing and
reports the interpret overhead honestly in its ``mode`` column.
"""

from __future__ import annotations

import time


def roundstep_rows(family: str, p: int = 8, n: int = 8,
                   sizes=(1 << 10, 1 << 16, 1 << 20), iters: int = 50):
    """Time one steady-state fused round step per backend and size.

    ``family``: ``"bcast"`` (shuffle over an [p, n+1, bs] buffer) or
    ``"allreduce"`` (acc_shuffle with op="sum" over [p, n+2, bs]).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.roundstep import get_round_step

    nslots = n + 1 if family == "bcast" else n + 2
    rng = np.random.default_rng(0 if family == "bcast" else 1)
    rows = []
    for m in sizes:
        bs = max(1, m // (4 * n))
        buf = jnp.asarray(rng.normal(size=(p, nslots, bs)), jnp.float32)
        msg = jnp.asarray(rng.normal(size=(p, bs)), jnp.float32)
        ia = jnp.asarray(rng.integers(0, n + 1, size=p), jnp.int32)
        ib = jnp.asarray(rng.integers(0, n + 1, size=p), jnp.int32)
        for backend in ("jnp", "pallas"):
            step = get_round_step(backend)
            if family == "bcast":
                f = jax.jit(step.shuffle)
            else:
                f = jax.jit(lambda b, g, a, w: step.acc_shuffle(b, g, a, w,
                                                                op="sum"))
            jax.block_until_ready(f(buf, msg, ia, ib))  # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(f(buf, msg, ia, ib))
            us = (time.perf_counter() - t0) / iters * 1e6
            rows.append({"backend": backend, "m": m, "n": n, "us": us,
                         "mode": ("interpret"
                                  if getattr(step, "interpret", False)
                                  else "compiled" if backend == "pallas"
                                  else "xla")})
    return rows


def roundstep_main(family: str, p: int = 8, n: int = 8):
    print("name,backend,mode,m_bytes,n_blocks,us_per_round_step")
    for r in roundstep_rows(family, p=p, n=n):
        print(f"{family}_roundstep,{r['backend']},{r['mode']},{r['m']},"
              f"{r['n']},{r['us']:.1f}")
