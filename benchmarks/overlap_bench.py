"""Overlapped-executor benchmark: double-buffered vs sequential rounds.

    PYTHONPATH=src python -m benchmarks.run overlap

Times the two round-loop executors of the collective plans -- the
sequential loop (exchange, then fused unpack+pack) and the overlapped
loop (``overlap=True``: next round's block packed from the pre-update
buffer while the exchange is in flight, staged step patches the bypass
slot) -- and writes ``BENCH_overlap.json`` at the repo root (committed,
so the numbers version with the code).

Committed JSON schema (``schema: 1``; times are medians over iters):

    {
      "schema": 1,
      "note": ...,                     # honest caveat about the testbed
      "roundloop": [                   # measured per-op, composed rounds
        {"backend": ..., "p": ..., "n": ..., "block_bytes": ...,
         "pack_us": ..., "unpack_us": ...,   # round-step op medians
         "shuffle_us": ..., "staged_us": ...,
         "exchange_us": ...,           # wire proxy (all-rank rotation)
         "round_seq_us": ...,          # exchange + shuffle
         "round_overlap_us": ...,      # max(exchange, pack) + staged-patch
         "speedup": ...},
        ...
      ],
      "device": [                      # subprocess, forced host devices
        {"kind": ..., "p": ..., "m_bytes": ..., "backend": ...,
         "sequential_us": ..., "overlap_us": ..., "speedup": ...},
        ...
      ]
    }

The ``roundloop`` rows compose measured op medians along each
executor's critical path: sequentially the wire waits for the fused
unpack+pack of the previous round, overlapped the pack runs while the
exchange is in flight (``max``), leaving only the staged patch on the
path.  That composition is the round-loop improvement the mode is for
-- it assumes the wire is asynchronous w.r.t. local compute, which
holds for real interconnects but NOT for XLA host devices on one CPU.
The ``device`` rows therefore time the full jitted plans end-to-end on
host devices, where the extra pre-pack is serialized instead of hidden:
sequential wins those rows by construction, and the gap bounds the work
the mode hides on a real interconnect.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_overlap.json")

#: (p, n, block elements) for the round-loop op timings.  1 MB blocks:
#: large enough that every op runs well above timer noise, small enough
#: that the n+1-slot buffer stays cache-resident (bigger blocks thrash
#: LLC on the host testbed and the medians stop converging).
ROUNDLOOP_CASES = [(8, 4, 1 << 18), (8, 8, 1 << 18)]
#: (kind, p, f32 payload bytes) for the end-to-end device rows.
DEVICE_CASES = [("broadcast", 8, 1 << 22), ("allreduce", 8, 1 << 22)]
ITERS = 50


def _median_us(fn, iters: int = ITERS) -> float:
    import jax

    jax.block_until_ready(fn())  # compile once
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return round(sorted(ts)[len(ts) // 2] * 1e6, 1)


def roundloop_rows():
    """Measured round-step op medians, composed along each executor's
    critical path (see the module docstring for what the composition
    assumes)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.roundstep import BACKENDS, get_round_step

    rng = np.random.default_rng(0)
    rows = []
    for backend in BACKENDS:
        step = get_round_step(backend)
        for p, n, bs in ROUNDLOOP_CASES:
            buf = jnp.asarray(rng.standard_normal((1, n + 1, bs)),
                              jnp.float32)
            msg = jnp.asarray(rng.standard_normal((1, bs)), jnp.float32)
            wire = jnp.asarray(rng.standard_normal((p, bs)), jnp.float32)
            idx = jnp.zeros((1,), jnp.int32)
            recv, send = jnp.full((1,), 1, jnp.int32), jnp.full(
                (1,), 2, jnp.int32)
            pack = _median_us(lambda: step.pack(buf, idx))
            unpack = _median_us(lambda: step.unpack(buf, msg, idx))
            shuffle = _median_us(lambda: step.shuffle(buf, msg, recv, send))
            pre = step.pack(buf, send)
            staged = _median_us(
                lambda: step.shuffle_staged(buf, msg, pre, recv, send))
            # wire proxy: the all-rank rotation ppermute lowers to on one
            # host (bandwidth-equivalent; no network latency term).
            exch = _median_us(lambda: jnp.roll(wire, 1, axis=0))
            # staged patch alone (unpack + bypass select) = staged minus
            # the pack it no longer performs, bounded below by unpack.
            patch = max(unpack, round(staged - pack, 1))
            seq = round(exch + shuffle, 1)
            ovl = round(max(exch, pack) + patch, 1)
            rows.append({
                "backend": backend, "p": p, "n": n,
                "block_bytes": 4 * bs,
                "pack_us": pack, "unpack_us": unpack,
                "shuffle_us": shuffle, "staged_us": staged,
                "exchange_us": exch,
                "round_seq_us": seq, "round_overlap_us": ovl,
                "speedup": round(seq / ovl, 3),
            })
    return rows


_DEVICE_CODE = r"""
import json, time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.comm import get_comm

def median_us(fn, iters=20):
    jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return round(sorted(ts)[len(ts) // 2] * 1e6, 1)

p = len(jax.devices())
mesh = Mesh(np.array(jax.devices()), ("data",))
comm = get_comm(mesh, "data", backend="jnp")
rows = []
for kind, pp, m in %s:
    assert pp == p
    elems = m // 4
    rng = np.random.default_rng(1)
    x = {"g": jax.device_put(
        jnp.asarray(rng.standard_normal((p, elems // p)), jnp.float32),
        NamedSharding(mesh, P("data")))}
    row = {"kind": kind, "p": p, "m_bytes": m, "backend": "jnp"}
    for label, overlap in (("sequential", False), ("overlap", True)):
        plan = comm.plan(kind, x, root=0, overlap=overlap)
        row[label + "_us"] = median_us(lambda: plan(x))
    row["speedup"] = round(row["sequential_us"] / row["overlap_us"], 3)
    rows.append(row)
print("JSON" + json.dumps(rows))
"""


def device_rows(p: int = 8):
    """End-to-end jitted plans, sequential vs overlap, in a subprocess
    with p forced host devices (parity expected; see module docstring)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    code = _DEVICE_CODE % repr([c for c in DEVICE_CASES if c[1] == p])
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    for line in res.stdout.splitlines():
        if line.startswith("JSON"):
            return json.loads(line[4:])
    raise RuntimeError("overlap device benchmark produced no JSON row")


NOTE = ("roundloop rows compose measured op medians assuming an "
        "asynchronous wire (the overlap design target); device rows are "
        "XLA host devices on one CPU with no async interconnect, so the "
        "overlapped loop's extra pre-pack is serialized instead of "
        "hidden there -- sequential wins those rows by construction, and "
        "the gap bounds the work the mode hides on a real interconnect")


def main(write_json: bool = True):
    roundloop = roundloop_rows()
    print("name,backend,p,n,block_bytes,pack_us,shuffle_us,staged_us,"
          "exchange_us,round_seq_us,round_overlap_us,speedup")
    for r in roundloop:
        print(f"overlap_roundloop,{r['backend']},{r['p']},{r['n']},"
              f"{r['block_bytes']},{r['pack_us']},{r['shuffle_us']},"
              f"{r['staged_us']},{r['exchange_us']},{r['round_seq_us']},"
              f"{r['round_overlap_us']},{r['speedup']}")
    device = device_rows()
    print("name,kind,p,m_bytes,backend,sequential_us,overlap_us,speedup")
    for r in device:
        print(f"overlap_device,{r['kind']},{r['p']},{r['m_bytes']},"
              f"{r['backend']},{r['sequential_us']},{r['overlap_us']},"
              f"{r['speedup']}")
    if write_json:
        payload = {"schema": 1, "note": NOTE, "roundloop": roundloop,
                   "device": device}
        with open(OUT_PATH, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {os.path.relpath(OUT_PATH, ROOT)}")


if __name__ == "__main__":
    main()
