"""Benchmark driver: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table3     # one section

Output is CSV (name,...) so EXPERIMENTS.md tables can be regenerated.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    t0 = time.time()

    if which in ("table3", "all"):
        print("# === Table 3: schedule computation timing ===")
        from benchmarks import schedule_timing

        schedule_timing.main("table3")

    if which in ("engine", "all"):
        print("# === Engine: batched/cached all-rank tables vs per-rank loop ===")
        from benchmarks import schedule_timing

        schedule_timing.main("engine")

    if which in ("fig1", "all"):
        print("# === Figure 1: broadcast ===")
        from benchmarks import bcast_bench

        bcast_bench.main()

    if which in ("fig23", "all"):
        print("# === Figures 2-3: (irregular) allgather ===")
        from benchmarks import allgatherv_bench

        allgatherv_bench.main()

    if which in ("allreduce", "all"):
        print("# === Reversed family: all-reduction vs classic algorithms ===")
        from benchmarks import allreduce_bench

        allreduce_bench.main()

    if which in ("comm", "all"):
        print("# === Communicator: plan-cached vs per-call dispatch ===")
        from benchmarks import comm_bench

        comm_bench.main()

    if which in ("hier", "all"):
        print("# === Hierarchical: flat vs two-level on the 36x32 topology ===")
        from benchmarks import hier_bench

        hier_bench.main()

    if which in ("gradsync", "all"):
        print("# === Gradient sync: quantized circulant vs ring vs GSPMD ===")
        from benchmarks import gradsync_bench

        gradsync_bench.main()

    if which in ("overlap", "all"):
        print("# === Overlapped executor: double-buffered vs sequential rounds ===")
        from benchmarks import overlap_bench

        overlap_bench.main()

    if which in ("roundstep", "all"):
        print("# === Round-step data plane: jnp vs pallas backends ===")
        from benchmarks import allreduce_bench, bcast_bench

        bcast_bench.roundstep_main()
        allreduce_bench.roundstep_main()

    if which in ("analysis", "all"):
        print("# === Static analysis: per-pass analyzer runtime ===")
        from repro.analysis.__main__ import main as analysis_main

        rc = analysis_main(["--all", "--bench", "BENCH_analysis.json"])
        assert rc == 0, "static analysis found violations"

    if which in ("verify", "all"):
        print("# === Correctness sweep (paper section 3 verification) ===")
        from repro.core.verify import verify_p

        t = time.time()
        ps = list(range(1, 1025)) + [2048, 4096, 8191, 65536, 65537, 1 << 20]
        for p in ps:
            verify_p(p)
        print(f"verify,{len(ps)}_values_of_p_up_to_{max(ps)},"
              f"{time.time()-t:.1f}s,forward_and_reversed_conditions_hold")

    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
