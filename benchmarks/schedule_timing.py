"""Paper Table 3: schedule-computation timing, legacy vs new.

For each p in a range, compute receive + send schedules for all
processors r in 0..p-1 with (a) the legacy O(log^2 p)/O(log^3 p)
constructions and (b) the new O(log p) algorithms; report total seconds
and the average per-processor microseconds, exactly the two columns of
the paper's Table 3 (ranges are scaled to CI time; pass --full for the
paper's ranges).
"""

from __future__ import annotations

import time

from repro.core.reference import recv_schedule_legacy, send_schedule_legacy
from repro.core.schedule import compute_skips, recv_schedule, send_schedule

# CI-sized p ranges (paper uses [1,17000] ... [2097000,2099000]); for
# p above SAMPLE_RANKS we time a uniform sample of ranks and report the
# per-processor average (the paper's metric), since pure-Python timing
# of 262k+ ranks per p is a CPU-hours exercise that measures the same
# asymptotics.
RANGES = [
    (1, 400, None),
    (4000, 4016, None),
    (16000, 16008, 2048),
    (65000, 65004, 1024),
    (262000, 262002, 512),
    (1048575, 1048577, 256),
]

FULL_RANGES = [(lo, hi, None) for lo, hi in
               [(1, 17000), (16000, 33000), (64000, 73000)]]


def time_range(lo: int, hi: int, new: bool, max_ranks=None):
    t0 = time.perf_counter()
    per_p = []
    for p in range(lo, hi):
        skip = compute_skips(p)
        stride = max(1, p // max_ranks) if max_ranks else 1
        ranks = range(0, p, stride)
        t1 = time.perf_counter()
        if new:
            for r in ranks:
                recv_schedule(p, r, skip)
                send_schedule(p, r, skip)
        else:
            for r in ranks:
                recv_schedule_legacy(p, r, skip)
                send_schedule_legacy(p, r, skip)
        per_p.append((time.perf_counter() - t1) / max(len(ranks), 1))
    total = time.perf_counter() - t0
    avg_us = 1e6 * sum(per_p) / len(per_p)
    return total, avg_us


def run(full: bool = False):
    rows = []
    for lo, hi, max_ranks in (FULL_RANGES if full else RANGES):
        t_old, us_old = time_range(lo, hi, new=False, max_ranks=max_ranks)
        t_new, us_new = time_range(lo, hi, new=True, max_ranks=max_ranks)
        rows.append({
            "range": f"[{lo},{hi})",
            "total_s_legacy": round(t_old, 2),
            "total_s_new": round(t_new, 2),
            "us_per_proc_legacy": round(us_old, 3),
            "us_per_proc_new": round(us_new, 3),
            "speedup": round(us_old / max(us_new, 1e-12), 1),
        })
    return rows


def main():
    print("name,range,total_s_legacy,total_s_new,us_legacy,us_new,speedup")
    for row in run():
        print(
            f"table3,{row['range']},{row['total_s_legacy']},{row['total_s_new']},"
            f"{row['us_per_proc_legacy']},{row['us_per_proc_new']},{row['speedup']}"
        )


if __name__ == "__main__":
    main()
