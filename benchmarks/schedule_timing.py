"""Paper Table 3: schedule-computation timing, legacy vs new -- plus the
engine's batched/cached all-rank path.

Two sections:

  * ``table3``: for each p in a range, compute receive + send schedules
    for all processors r in 0..p-1 with (a) the legacy
    O(log^2 p)/O(log^3 p) constructions and (b) the new O(log p)
    algorithms; report total seconds and the average per-processor
    microseconds, exactly the two columns of the paper's Table 3 (ranges
    are scaled to CI time; pass --full for the paper's ranges).

  * ``engine``: all-rank [p, q] table materialization, per-rank Python
    loop (Algorithms 6 + 7-9 per rank, as the seed's consumers did)
    vs the engine's batched path (per-rank Algorithm 6 + one vectorized
    NumPy gather for the send table via Proposition 4) vs a warm
    process-wide cache hit.  The engine must win for p >= 1024.
"""

from __future__ import annotations

import time

from repro.core.engine import bundle_cache_clear, get_bundle
from repro.core.reference import recv_schedule_legacy, send_schedule_legacy
from repro.core.schedule import compute_skips, recv_schedule, send_schedule

# CI-sized p ranges (paper uses [1,17000] ... [2097000,2099000]); for
# p above SAMPLE_RANKS we time a uniform sample of ranks and report the
# per-processor average (the paper's metric), since pure-Python timing
# of 262k+ ranks per p is a CPU-hours exercise that measures the same
# asymptotics.
RANGES = [
    (1, 400, None),
    (4000, 4016, None),
    (16000, 16008, 2048),
    (65000, 65004, 1024),
    (262000, 262002, 512),
    (1048575, 1048577, 256),
]

FULL_RANGES = [(lo, hi, None) for lo, hi in
               [(1, 17000), (16000, 33000), (64000, 73000)]]


def time_range(lo: int, hi: int, new: bool, max_ranks=None):
    t0 = time.perf_counter()
    per_p = []
    for p in range(lo, hi):
        skip = compute_skips(p)
        stride = max(1, p // max_ranks) if max_ranks else 1
        ranks = range(0, p, stride)
        t1 = time.perf_counter()
        if new:
            for r in ranks:
                recv_schedule(p, r, skip)
                send_schedule(p, r, skip)
        else:
            for r in ranks:
                recv_schedule_legacy(p, r, skip)
                send_schedule_legacy(p, r, skip)
        per_p.append((time.perf_counter() - t1) / max(len(ranks), 1))
    total = time.perf_counter() - t0
    avg_us = 1e6 * sum(per_p) / len(per_p)
    return total, avg_us


def run(full: bool = False):
    rows = []
    for lo, hi, max_ranks in (FULL_RANGES if full else RANGES):
        t_old, us_old = time_range(lo, hi, new=False, max_ranks=max_ranks)
        t_new, us_new = time_range(lo, hi, new=True, max_ranks=max_ranks)
        rows.append({
            "range": f"[{lo},{hi})",
            "total_s_legacy": round(t_old, 2),
            "total_s_new": round(t_new, 2),
            "us_per_proc_legacy": round(us_old, 3),
            "us_per_proc_new": round(us_new, 3),
            "speedup": round(us_old / max(us_new, 1e-12), 1),
        })
    return rows


ENGINE_PS = [256, 1024, 4096, 16384]


def _best_of(fn, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def engine_rows(ps=None, repeats=3):
    """Engine all-rank table path vs the per-rank loop, per p (best of
    ``repeats`` runs each, so one noisy scheduler tick can't flip the
    comparison).

    ``per_rank_ms``: recv_schedule + send_schedule for every rank into
    Python lists (what every consumer did before the engine).
    ``engine_cold_ms``: get_bundle on an empty cache (per-rank recv +
    vectorized send derivation).  ``engine_warm_ms``: get_bundle again
    (process-wide LRU hit; this is what collectives/restores pay).
    """
    rows = []
    for p in ps or ENGINE_PS:
        skip = compute_skips(p)

        def per_rank_loop():
            for r in range(p):
                recv_schedule(p, r, skip)
                send_schedule(p, r, skip)

        def engine_cold():
            bundle_cache_clear()
            get_bundle(p)

        per_rank = _best_of(per_rank_loop, repeats)
        cold = _best_of(engine_cold, repeats)
        warm = _best_of(lambda: get_bundle(p), repeats)

        # The consumer-facing comparison: every consumer materializes the
        # tables more than once per process (one per jit trace / sim run /
        # restore); the engine pays cold once then hits the cache.  Three
        # uses is a conservative stand-in.
        uses = 3
        amortized = (uses * per_rank) / max(cold + (uses - 1) * warm, 1e-12)

        rows.append({
            "p": p,
            "per_rank_ms": round(per_rank * 1e3, 3),
            "engine_cold_ms": round(cold * 1e3, 3),
            "engine_warm_ms": round(warm * 1e6) / 1e3,  # keep sub-us resolution
            "amortized_speedup_3_uses": round(amortized, 2),
            "warm_speedup": round(per_rank / max(warm, 1e-12), 1),
        })
    return rows


def main(which: str = "all", full: bool = False):
    if which not in ("table3", "engine", "all"):
        raise SystemExit(
            f"unknown section {which!r}; usage: schedule_timing.py "
            "[table3|engine|all] [--full]"
        )
    if which in ("table3", "all"):
        print("name,range,total_s_legacy,total_s_new,us_legacy,us_new,speedup")
        for row in run(full):
            print(
                f"table3,{row['range']},{row['total_s_legacy']},{row['total_s_new']},"
                f"{row['us_per_proc_legacy']},{row['us_per_proc_new']},{row['speedup']}"
            )
    if which in ("engine", "all"):
        print("name,p,per_rank_ms,engine_cold_ms,engine_warm_ms,"
              "amortized_speedup_3_uses,warm_speedup")
        for row in engine_rows():
            print(
                f"engine,{row['p']},{row['per_rank_ms']},{row['engine_cold_ms']},"
                f"{row['engine_warm_ms']},{row['amortized_speedup_3_uses']},"
                f"{row['warm_speedup']}"
            )


if __name__ == "__main__":
    import sys

    argv = sys.argv[1:]
    full = "--full" in argv
    argv = [a for a in argv if a != "--full"]
    main(argv[0] if argv else "all", full=full)
