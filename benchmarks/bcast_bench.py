"""Paper Figure 1: broadcast, circulant n-block vs classic algorithms.

Two complementary measurements (no real cluster in this container):

  1. alpha-beta model sweep over message size m and p = 36*32 = 1152
     (the paper's cluster size): circulant with the analytically-optimal
     n vs binomial tree vs scatter-allgather vs linear pipeline.
  2. wall-clock on host devices (subprocess, p=8): the JAX circulant
     broadcast vs XLA's native broadcast path and ring allgather-based
     bcast, in microseconds per call.
"""

from __future__ import annotations

import os
import subprocess
import sys

from repro.core.costmodel import (
    CommModel,
    bcast_binomial_cost,
    bcast_circulant_cost,
    bcast_linear_pipeline_cost,
    bcast_scatter_allgather_cost,
    optimal_num_blocks_bcast,
)
from repro.core.engine import get_bundle

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

P_CLUSTER = 36 * 32
SIZES = [1 << k for k in range(6, 27, 2)]  # 64 B .. 64 MB


def model_rows(p: int = P_CLUSTER, model: CommModel = CommModel(alpha=2e-6, beta=1 / 10e9)):
    # One cached bundle serves the whole sweep (and anything else this
    # process later runs at the same p).
    bundle = get_bundle(p)
    rows = []
    for m in SIZES:
        n = optimal_num_blocks_bcast(p, m, model)
        rows.append({
            "m": m,
            "n_opt": n,
            "rounds": bundle.rounds(max(1, n)),
            "circulant_us": 1e6 * bcast_circulant_cost(p, m, n, model),
            "binomial_us": 1e6 * bcast_binomial_cost(p, m, model),
            "scatter_ag_us": 1e6 * bcast_scatter_allgather_cost(p, m, model),
            "pipeline_us": 1e6 * bcast_linear_pipeline_cost(
                p, m, max(1, n), model),
        })
    return rows


def wallclock_rows(p: int = 8):
    """Run the host-device wall-clock benchmark in a subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    code = r"""
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.collectives import circulant_broadcast, ring_allgather
p = len(jax.devices())
mesh = Mesh(np.array(jax.devices()), ("data",))
for m in (1024, 65536, 1048576):
    elems = m // 4
    x = jax.device_put(jnp.zeros((p, elems), jnp.float32), NamedSharding(mesh, P("data")))
    for name, fn in [
        ("circulant_n1", lambda a: circulant_broadcast(mesh, "data", a, n_blocks=1)),
        ("circulant_nopt", lambda a: circulant_broadcast(mesh, "data", a)),
        ("ring_ag", lambda a: ring_allgather(mesh, "data", a)),
    ]:
        f = jax.jit(fn)
        f(x)[0].block_until_ready() if hasattr(f(x), '__getitem__') else None
        t0 = time.perf_counter(); it = 20
        for _ in range(it):
            r = f(x)
            jax.tree.leaves(r)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / it
        print(f"WC,{name},{m},{dt*1e6:.1f}")
"""
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    rows = []
    for line in res.stdout.splitlines():
        if line.startswith("WC,"):
            _, name, m, us = line.split(",")
            rows.append({"impl": name, "m": int(m), "us": float(us)})
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return rows


def roundstep_main(p: int = 8, n: int = 8):
    """jnp-vs-pallas timing of one fused broadcast round step (the
    unpack+pack shuffle); shared sweep in ``roundstep_common``."""
    from benchmarks.roundstep_common import roundstep_main as rs_main

    rs_main("bcast", p=p, n=n)


def main():
    print("name,m_bytes,n_opt,rounds,circulant_us,binomial_us,scatter_ag_us,"
          "pipeline_us")
    for r in model_rows():
        print(f"fig1_model,{r['m']},{r['n_opt']},{r['rounds']},{r['circulant_us']:.1f},"
              f"{r['binomial_us']:.1f},{r['scatter_ag_us']:.1f},{r['pipeline_us']:.1f}")
    print("name,impl,m_bytes,us_per_call")
    for r in wallclock_rows():
        print(f"fig1_wallclock,{r['impl']},{r['m']},{r['us']:.1f}")


if __name__ == "__main__":
    main()
