"""Paper Figures 2-3: irregular allgather (allgatherv), circulant vs ring.

Problem types exactly as in the paper:
  * regular    -- every rank contributes m/p,
  * irregular  -- rank i contributes (i mod 3) * m/p (plus 1),
  * degenerate -- rank 0 contributes everything, others nothing.

For each, wall-clock on p=8 host devices of the circulant allgatherv
(whose per-round wire volume tracks sum(sizes)) vs a padded ring
allgather (whose volume is p * max(sizes) -- the degenerate case is
where the paper's native-MPI baseline loses a factor ~100).  Plus the
alpha-beta model sweep at the paper's p = 1152.
"""

from __future__ import annotations

import os
import subprocess
import sys

from repro.core.costmodel import (
    CommModel,
    allgather_bruck_cost,
    allgather_circulant_cost,
    allgather_ring_cost,
    optimal_num_blocks_allgather,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SIZES = [1 << k for k in range(8, 25, 2)]


def model_rows(p: int = 36 * 32, model: CommModel = CommModel(alpha=2e-6, beta=1 / 10e9)):
    rows = []
    for m in SIZES:
        n = optimal_num_blocks_allgather(p, m, model)
        rows.append({
            "m": m, "n_opt": n,
            "circulant_us": 1e6 * allgather_circulant_cost(p, m, n, model),
            "ring_us": 1e6 * allgather_ring_cost(p, m, model),
            "bruck_us": 1e6 * allgather_bruck_cost(p, m, model),
        })
    return rows


def wallclock_rows(p: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    code = r"""
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.collectives import circulant_allgatherv, ring_allgather
p = len(jax.devices())
mesh = Mesh(np.array(jax.devices()), ("data",))
total = 1 << 20  # elements (4 MB): bandwidth-dominated on the host too
problems = {
    "regular": [total // p] * p,
    "irregular": [max(1, (i % 3) * total // p) for i in range(p)],
    "degenerate": [total] + [1] * (p - 1),
}
for kind, sizes in problems.items():
    cap = max(sizes)
    x = jax.device_put(jnp.zeros((p, cap), jnp.float32), NamedSharding(mesh, P("data")))
    fv = jax.jit(lambda a: circulant_allgatherv(mesh, "data", a, sizes, n_blocks=2))
    fr = jax.jit(lambda a: ring_allgather(mesh, "data", a))  # padded to cap
    for name, f in (("circulant_v", fv), ("ring_padded", fr)):
        f(x).block_until_ready()
        t0 = time.perf_counter(); it = 10
        for _ in range(it):
            f(x).block_until_ready()
        dt = (time.perf_counter() - t0) / it
        print(f"WC,{kind},{name},{dt*1e6:.1f}")
"""
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    rows = []
    for line in res.stdout.splitlines():
        if line.startswith("WC,"):
            _, kind, name, us = line.split(",")
            rows.append({"kind": kind, "impl": name, "us": float(us)})
    return rows


def main():
    print("name,m_bytes,n_opt,circulant_us,ring_us,bruck_us")
    for r in model_rows():
        print(f"fig23_model,{r['m']},{r['n_opt']},{r['circulant_us']:.1f},"
              f"{r['ring_us']:.1f},{r['bruck_us']:.1f}")
    print("name,problem,impl,us_per_call")
    for r in wallclock_rows():
        print(f"fig23_wallclock,{r['kind']},{r['impl']},{r['us']:.1f}")


if __name__ == "__main__":
    main()
