"""Reversed-schedule family: reduction / all-reduction benchmarks.

Two complementary measurements (no real cluster in this container):

  1. alpha-beta model sweep over message size m at the paper's cluster
     size p = 36*32 = 1152: the circulant all-reduction (reversed reduce
     + forward broadcast, 2(n-1)+2q rounds) with the analytically
     optimal n vs ring all-reduce (2(p-1) rounds, bandwidth-optimal) vs
     recursive doubling (q rounds of the full message) vs binomial
     reduce + broadcast.
  2. wall-clock on host devices (subprocess, p=8): the JAX
     circulant_allreduce vs XLA's native psum path, microseconds/call.
"""

from __future__ import annotations

import os
import subprocess
import sys

from repro.core.costmodel import (
    CommModel,
    allreduce_circulant_cost,
    allreduce_recursive_doubling_cost,
    allreduce_ring_cost,
    bcast_binomial_cost,
    optimal_num_blocks_allreduce,
    reduce_binomial_cost,
)
from repro.core.engine import get_bundle

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

P_CLUSTER = 36 * 32
SIZES = [1 << k for k in range(6, 27, 2)]  # 64 B .. 64 MB


def model_rows(p: int = P_CLUSTER, model: CommModel = CommModel(alpha=2e-6, beta=1 / 10e9)):
    # Forward AND reversed phases come from this one cached bundle.
    bundle = get_bundle(p)
    rows = []
    for m in SIZES:
        n = optimal_num_blocks_allreduce(p, m, model)
        rows.append({
            "m": m,
            "n_opt": n,
            "rounds": bundle.allreduce_rounds(max(1, n)),
            "circulant_us": 1e6 * allreduce_circulant_cost(p, m, n, model),
            "ring_us": 1e6 * allreduce_ring_cost(p, m, model),
            "recdoub_us": 1e6 * allreduce_recursive_doubling_cost(p, m, model),
            "binomial_us": 1e6 * (reduce_binomial_cost(p, m, model)
                                  + bcast_binomial_cost(p, m, model)),
        })
    return rows


def wallclock_rows(p: int = 8):
    """Run the host-device wall-clock benchmark in a subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    code = r"""
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.collectives import circulant_allreduce
from repro.core.jaxcompat import shard_map
p = len(jax.devices())
mesh = Mesh(np.array(jax.devices()), ("data",))
def native_psum(a):
    return shard_map(lambda xs: jax.lax.psum(xs, "data"),
                     mesh=mesh, in_specs=P("data"), out_specs=P(),
                     check_vma=False)(a)
for m in (1024, 65536, 1048576):
    elems = m // 4
    x = jax.device_put(jnp.ones((p, elems), jnp.float32), NamedSharding(mesh, P("data")))
    for name, fn in [
        ("circulant_n1", lambda a: circulant_allreduce(mesh, "data", a, n_blocks=1)),
        ("circulant_nopt", lambda a: circulant_allreduce(mesh, "data", a)),
        ("native_psum", native_psum),
    ]:
        f = jax.jit(fn)
        jax.tree.leaves(f(x))[0].block_until_ready()
        t0 = time.perf_counter(); it = 20
        for _ in range(it):
            r = f(x)
            jax.tree.leaves(r)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / it
        print(f"WC,{name},{m},{dt*1e6:.1f}")
"""
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    rows = []
    for line in res.stdout.splitlines():
        if line.startswith("WC,"):
            _, name, m, us = line.split(",")
            rows.append({"impl": name, "m": int(m), "us": float(us)})
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return rows


def roundstep_main(p: int = 8, n: int = 8):
    """jnp-vs-pallas timing of one fused reduce round step (the
    accumulate+capture/drain, op="sum"); shared sweep in
    ``roundstep_common``."""
    from benchmarks.roundstep_common import roundstep_main as rs_main

    rs_main("allreduce", p=p, n=n)


def main():
    print("name,m_bytes,n_opt,rounds,circulant_us,ring_us,recdoub_us,binomial_us")
    for r in model_rows():
        print(f"allreduce_model,{r['m']},{r['n_opt']},{r['rounds']},"
              f"{r['circulant_us']:.1f},{r['ring_us']:.1f},"
              f"{r['recdoub_us']:.1f},{r['binomial_us']:.1f}")
    print("name,impl,m_bytes,us_per_call")
    for r in wallclock_rows():
        print(f"allreduce_wallclock,{r['impl']},{r['m']},{r['us']:.1f}")


if __name__ == "__main__":
    main()
