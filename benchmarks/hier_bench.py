"""Hierarchical (two-level) collective benchmark: flat vs composed cost
on the paper's 36x32 evaluation topology, plus plan-machinery timings.

    PYTHONPATH=src python -m benchmarks.run hier

Two sections, CSV rows:

  * ``hier_cost``: modeled alpha-beta cost of the flat circulant
    collective over p = nodes*cores (every hop priced at the inter-node
    link) vs the two-level composition (inter hops at the slow model,
    intra hops at the fast model), each at its own optimal block
    count -- the quantitative case for the hierarchy on asymmetric
    fabrics.
  * ``hier_plan``: cold vs cached hierarchical host-plan construction
    and the certified 36x32 simulator sweep timing (the CI budget
    guard for the paper-topology certification tests).
"""

from __future__ import annotations

import time

from benchmarks.comm_bench import _median


def cost_rows():
    from repro.core.costmodel import (
        CommModel,
        bcast_circulant_cost,
        hier_cost,
        optimal_hier_blocks,
        optimal_num_blocks_bcast,
    )

    # Asymmetric fabric: inter-node ~ IB-ish latency/bandwidth, the
    # intra-node link an order of magnitude cheaper on both terms.
    inter = CommModel(alpha=2e-6, beta=1.0 / 12.5e9)
    intra = CommModel(alpha=2e-7, beta=1.0 / 200e9)
    nodes, cores = 36, 32
    p = nodes * cores
    print("name,nodes,cores,m_bytes,flat_n,flat_cost_us,"
          "hier_n_inter,hier_n_intra,hier_cost_us,speedup")
    for mexp in (12, 16, 20, 24):
        m = float(1 << mexp)
        nf = optimal_num_blocks_bcast(p, m, inter)
        flat = bcast_circulant_cost(p, m, nf, inter)
        nN, nC = optimal_hier_blocks(nodes, cores, m, m, inter, intra)
        hier = hier_cost("broadcast", nodes, cores, m, m, nN, nC,
                         inter, intra)
        print(f"hier_cost_bcast,{nodes},{cores},{int(m)},{nf},"
              f"{flat*1e6:.2f},{nN},{nC},{hier*1e6:.2f},"
              f"{flat/hier:.2f}")
        hier2 = hier_cost("allreduce", nodes, cores, m, m, nN, nC,
                          inter, intra)
        flat2 = 2 * flat
        print(f"hier_cost_allreduce,{nodes},{cores},{int(m)},{nf},"
              f"{flat2*1e6:.2f},{nN},{nC},{hier2*1e6:.2f},"
              f"{flat2/hier2:.2f}")


def plan_rows():
    from repro.core.engine import plan_cache_clear
    from repro.core.hier import hier_host_plan

    print("name,nodes,cores,n_inter,n_intra,value")
    plan_cache_clear()
    t0 = time.perf_counter()
    hier_host_plan("broadcast", 36, 32, 4, 3)
    cold = (time.perf_counter() - t0) * 1e3
    times = []
    for _ in range(200):
        t0 = time.perf_counter()
        hier_host_plan("broadcast", 36, 32, 4, 3)
        times.append((time.perf_counter() - t0) * 1e6)
    print(f"hier_plan_cold_ms,36,32,4,3,{cold:.3f}")
    print(f"hier_plan_cached_us,36,32,4,3,{_median(times):.2f}")

    from repro.core import (
        simulate_hier_allreduce,
        simulate_hier_broadcast,
        simulate_hier_reduce,
    )

    t0 = time.perf_counter()
    simulate_hier_broadcast(36, 32, 3, 2, root=1127, backend="jnp")
    simulate_hier_reduce(36, 32, 2, 2, root=100, backend="jnp")
    simulate_hier_allreduce(36, 32, 2, 1, backend="jnp")
    print(f"hier_sim36x32_certified_s,36,32,-,-,"
          f"{time.perf_counter() - t0:.2f}")


def main():
    cost_rows()
    plan_rows()


if __name__ == "__main__":
    main()
