"""Circulant collectives on real (host) devices via the communicator API.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/collective_demo.py

Runs the paper's n-block broadcast, an all-reduction, and the irregular
allgather as JAX collectives through the plan/execute front-end
(:mod:`repro.core.comm`): one `CirculantComm` per mesh axis, one
`CollectivePlan` per (kind, payload spec) precomputing the O(log p)
schedule work host-side, and plan calls that run only the traced
ppermute rounds.  Also broadcasts a mixed-dtype pytree in one shared
schedule and prints the per-round communication plan for one rank.
"""

import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.comm import get_comm
from repro.core.engine import get_bundle


def main():
    p = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("data",))
    comm = get_comm(mesh, "data")
    print(f"devices: {p}")

    # ---- the communication plan of rank 1 for a 5-block broadcast
    n = 5
    bundle = get_bundle(p)
    print(f"\nbroadcast plan p={p}, n={n}: rounds = n-1+q = {bundle.rounds(n)}, "
          f"virtual rounds x={bundle.virtual_rounds(n)}")
    r = 1
    print(f"rank {r}: recv sched {bundle.recv_row(r)}, send sched {bundle.send_row(r)}")
    for rnd, (k, off) in enumerate(bundle.round_plan(n)):
        rb = int(bundle.recv[r][k]) + off
        sb = int(bundle.send[r][k]) + off
        frm = int(bundle.neighbors_in[r][k])
        to = int(bundle.neighbors_out[r][k])
        print(f"  round {rnd}: recv block {rb if rb>=0 else '--'} from {frm}, "
              f"send block {sb if sb>=0 else '--'} to {to}")

    # ---- plan once, execute many
    rng = np.random.default_rng(0)
    data = rng.normal(size=(p, 1000)).astype(np.float32)
    xs = jax.device_put(jnp.asarray(data), NamedSharding(mesh, P("data")))
    plan = comm.plan("broadcast", xs, n_blocks=n)
    print(f"\nplan: {plan.describe()}")
    out = plan(xs)                      # first call compiles
    out = plan(xs)                      # later calls only dispatch
    assert np.allclose(np.asarray(out), data[0]), "broadcast mismatch"
    assert plan is comm.plan("broadcast", xs, n_blocks=n), "plan cache miss"
    print("CollectivePlan broadcast: every rank holds root's data  OK")

    # ---- pytree payload: mixed dtypes, ragged leaves, ONE shared schedule
    state = {
        "w": jax.device_put(jnp.asarray(rng.normal(size=(p, 37, 3)),
                                        jnp.float32),
                            NamedSharding(mesh, P("data"))),
        "step": jax.device_put(jnp.asarray(
            rng.integers(0, 100, size=(p, 11)), jnp.int32),
            NamedSharding(mesh, P("data"))),
    }
    tree_out = comm.broadcast(state, n_blocks=4, root=p - 1)
    for key, leaf in tree_out.items():
        ref = np.asarray(state[key])[p - 1]
        assert np.array_equal(np.asarray(leaf), np.broadcast_to(ref, leaf.shape))
    print("pytree broadcast (float32 + int32 leaves, one schedule)  OK")

    # ---- all-reduction on the same communicator
    vals = rng.integers(-100, 100, size=(p, 257)).astype(np.int32)
    red = comm.allreduce(
        jax.device_put(jnp.asarray(vals), NamedSharding(mesh, P("data"))),
        n_blocks=3)
    assert np.array_equal(np.asarray(red),
                          np.broadcast_to(vals.sum(0), vals.shape))
    print("circulant allreduce: every rank holds the sum  OK")

    # ---- irregular allgather, degenerate sizes (paper Figure 2's hard case)
    sizes = [900] + [20] * (p - 1)
    rows = np.zeros((p, max(sizes)), np.float32)
    for j in range(p):
        rows[j, : sizes[j]] = rng.normal(size=sizes[j])
    xs = jax.device_put(jnp.asarray(rows), NamedSharding(mesh, P("data")))
    out = np.asarray(comm.allgatherv(xs, sizes, n_blocks=3))
    for j in range(p):
        assert np.allclose(out[j, : sizes[j]], rows[j, : sizes[j]])
    print("circulant allgatherv (degenerate sizes): all rows delivered  OK")


if __name__ == "__main__":
    main()
