"""Circulant collectives on real (host) devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/collective_demo.py

Runs the paper's n-block broadcast and irregular allgather as JAX
collectives (shard_map + lax.ppermute rounds driven by the O(log p)
schedules) over 8 devices, checks results, and prints the per-round
communication plan for one rank.
"""

import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.collectives import circulant_allgatherv, circulant_broadcast
from repro.core.engine import get_bundle


def main():
    p = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("data",))
    print(f"devices: {p}")

    # ---- the communication plan of rank 1 for a 5-block broadcast
    n = 5
    bundle = get_bundle(p)
    print(f"\nbroadcast plan p={p}, n={n}: rounds = n-1+q = {bundle.rounds(n)}, "
          f"virtual rounds x={bundle.virtual_rounds(n)}")
    r = 1
    print(f"rank {r}: recv sched {bundle.recv_row(r)}, send sched {bundle.send_row(r)}")
    for rnd, (k, off) in enumerate(bundle.round_plan(n)):
        rb = int(bundle.recv[r][k]) + off
        sb = int(bundle.send[r][k]) + off
        frm = int(bundle.neighbors_in[r][k])
        to = int(bundle.neighbors_out[r][k])
        print(f"  round {rnd}: recv block {rb if rb>=0 else '--'} from {frm}, "
              f"send block {sb if sb>=0 else '--'} to {to}")

    # ---- run it
    rng = np.random.default_rng(0)
    data = rng.normal(size=(p, 1000)).astype(np.float32)
    xs = jax.device_put(jnp.asarray(data), NamedSharding(mesh, P("data")))
    out = jax.jit(lambda a: circulant_broadcast(mesh, "data", a, n_blocks=n))(xs)
    assert np.allclose(np.asarray(out), data[0]), "broadcast mismatch"
    print("\ncirculant_broadcast: every rank holds root's data  OK")

    # ---- irregular allgather, degenerate sizes (paper Figure 2's hard case)
    sizes = [900] + [20] * (p - 1)
    rows = np.zeros((p, max(sizes)), np.float32)
    for j in range(p):
        rows[j, : sizes[j]] = rng.normal(size=sizes[j])
    xs = jax.device_put(jnp.asarray(rows), NamedSharding(mesh, P("data")))
    out = np.asarray(jax.jit(
        lambda a: circulant_allgatherv(mesh, "data", a, sizes, n_blocks=3)
    )(xs))
    for j in range(p):
        assert np.allclose(out[j, : sizes[j]], rows[j, : sizes[j]])
    print("circulant_allgatherv (degenerate sizes): all rows delivered  OK")


if __name__ == "__main__":
    main()
