"""Serving demo: batched decode with continuous batching.

    PYTHONPATH=src python examples/serve_demo.py

Builds a small qwen2-family model, submits 6 requests with different
prompts/lengths into a 3-slot continuous-batching loop, and decodes
greedily.  Each slot tracks its own sequence position; finished slots
are re-admitted from the queue.
"""

import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.models.common import ModelConfig
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeLoop


def main():
    cfg = ModelConfig(
        name="serve-demo", family="dense", n_layers=4, d_model=192,
        n_heads=6, n_kv_heads=2, d_ff=768, vocab=2048, tie_embeddings=True,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    loop = ServeLoop(cfg, params, batch_slots=3, max_seq=64)
    reqs = [
        Request(rid=i, prompt=list(range(1 + i, 6 + i)), max_new=8 + 2 * i)
        for i in range(6)
    ]
    for r in reqs:
        loop.submit(r)

    t0 = time.time()
    steps = 0
    while loop.step() or loop.queue:
        steps += 1
        if steps > 500:
            break
    dt = time.time() - t0
    done = [r for r in reqs if r.done]
    toks = sum(len(r.out) for r in done)
    print(f"{len(done)}/{len(reqs)} requests finished, {toks} tokens in "
          f"{steps} engine steps ({dt:.1f}s, {toks/max(dt,1e-9):.1f} tok/s)")
    for r in reqs:
        print(f"  req {r.rid}: prompt {r.prompt} -> {r.out}")
    assert all(r.done for r in reqs), "not all requests finished"
    print("OK")


if __name__ == "__main__":
    main()
