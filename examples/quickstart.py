"""Quickstart: the paper's algorithms end-to-end in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py [p] [n]

1. computes the circulant-graph skips for p processors (Algorithm 3),
2. computes every rank's receive + send schedule in O(log p) each
   (Algorithms 5-9),
3. verifies the four correctness conditions of paper §2.1,
4. simulates the n-block broadcast (Algorithm 1): n-1+ceil(log2 p)
   rounds, payload-checked,
5. simulates the all-to-all broadcast (Algorithm 2),
6. prints the Table-2-style schedule for small p,
7. plans and executes a real JAX collective through the communicator
   API (:mod:`repro.core.comm`) on however many devices exist.
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    get_bundle,
    num_rounds,
    simulate_allgather,
    simulate_broadcast,
    verify_bundle,
)


def main():
    p = int(sys.argv[1]) if len(sys.argv) > 1 else 17
    n = int(sys.argv[2]) if len(sys.argv) > 2 else 7
    bundle = get_bundle(p)
    print(f"p={p}  q=ceil(log2 p)={bundle.q}  skips={list(bundle.skips)}")

    verify_bundle(bundle)
    print(f"schedules for all {p} ranks verified against the four "
          "correctness conditions (paper 2.1)")

    if p <= 40:
        print("\nrank : recvblock[0..q-1]        sendblock[0..q-1]")
        for r in range(p):
            print(f"{r:4d} : {str(bundle.recv_row(r)):24s} {bundle.send_row(r)}")

    res = simulate_broadcast(p, n)
    print(f"\nbroadcast  p={p} n={n}: delivered in {res.rounds} rounds "
          f"(optimal = n-1+q = {num_rounds(p, n)}), "
          f"{res.blocks_moved} block transfers (optimal = (p-1)*n = {(p-1)*n})")

    res = simulate_allgather(p, max(1, n // 2))
    print(f"allgather  p={p} n={max(1, n//2)}: delivered in {res.rounds} rounds "
          f"(optimal), {res.blocks_moved} block transfers")

    # ---- the communicator API on real devices (p = however many exist):
    # plan once (bundle + slot tables + jit executor), execute many.
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import get_comm

    pdev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("data",))
    comm = get_comm(mesh, "data")
    state = {"w": jnp.ones((pdev, 8), jnp.float32),
             "step": jnp.zeros((pdev, 3), jnp.int32)}
    plan = comm.plan("broadcast", state, n_blocks=2)
    out = plan(state)                       # only the traced rounds run
    assert plan is comm.plan("broadcast", state, n_blocks=2)
    assert np.array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    print(f"\ncomm plan/execute on {pdev} device(s): {plan.describe()}")
    print("\nOK")


if __name__ == "__main__":
    main()
