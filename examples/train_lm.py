"""End-to-end training driver: train a small LM on synthetic data with the
full substrate (data pipeline, AdamW, microbatching, checkpointing,
auto-resume).

    PYTHONPATH=src python examples/train_lm.py --steps 60           # quick
    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b \
        --full --steps 300 --batch 8                                # ~0.5B

Defaults train a ~20M-parameter qwen2-family model for 60 steps on CPU
(a few minutes); --full uses the real architecture config.  Kill it at
any point and re-run: it resumes from the last checkpoint and replays
the exact data stream.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def small_config(vocab=4096):
    return ModelConfig(
        name="lm-20m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab=vocab, tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--full", action="store_true",
                    help="use the real arch config (default: ~20M toy)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else small_config()
    print(f"model: {cfg.name}  params ~{cfg.param_count()/1e6:.1f}M")

    tcfg = TrainConfig(
        microbatches=args.microbatches,
        remat="full",
        opt=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
    )
    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start, state, extra = mgr.restore_latest(state)
    t0_step = int(extra.get("data_step", 0)) if start is not None else 0
    if start is not None:
        print(f"resumed from checkpoint step {start}")

    losses = []
    t0 = time.time()
    for i in range(t0_step, args.steps):
        batch = data.batch_at(i)
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 10 == 0:
            dt = (time.time() - t0) / max(1, len(losses))
            print(f"step {i+1:4d}  loss {losses[-1]:.4f}  "
                  f"grad_norm {float(m['grad_norm']):.3f}  {dt*1e3:.0f} ms/step")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state, extra={"data_step": i + 1})
    mgr.wait()

    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
